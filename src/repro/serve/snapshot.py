"""Crash-consistent snapshots of the full serving state.

A snapshot is a single file holding every byte the engine needs to resume
serving exactly where it left off:

- the `PagedKVCache` pools (int8 values + f32 scale siblings when quantized,
  bf16/f32 otherwise), the free list in exact order, refcounts, per-request
  block tables, and pool stats;
- the `RadixCache` tree (node keys, blocks, pins, LRU stamps, insertion
  seqs, per-request publish cursors, eviction clock, cache stats);
- the `Scheduler` queues (waiting / running / finished requests with full
  per-request state incl. `n_prefilled` chunk progress and decode-block
  reservations);
- the `ContinuousEngine` counters, stable decode-row assignment, on-device
  next-token vector, and PRNG key.

Container format (`SMXSNAP1`):

    SMXSNAP1 <header_len> <header_crc32>\n     magic line
    <header JSON, header_len bytes>            version, meta, section index
    <section 0 payload><section 1 payload>...  raw bytes, concatenated

Each section index entry records ``{name, kind, nbytes, crc32}`` (plus
``dtype``/``shape`` for arrays), so corruption is detected per-section
before any state is rebuilt.  JSON sections are UTF-8; array sections are
C-order raw bytes.  bfloat16 arrays are stored as their uint16 bit pattern
with the logical dtype recorded in the index.

Writes are atomic: payload goes to a same-directory temp file which is
fsync'd then `os.replace`'d over the target, so a crash mid-write leaves
either the old snapshot or none — never a torn one.

Recovery ladder (see `restore_engine`): clean snapshot -> warm start;
checksum or invariant (fsck) failure -> cold start, with terminal streams
recomputed from the journal alone.  Either way recovered greedy streams are
byte-identical to an uninterrupted run because decode is deterministic.
"""
from __future__ import annotations

import json
import os
import tempfile
import zlib
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

SNAPSHOT_MAGIC = "SMXSNAP1"
SNAPSHOT_VERSION = 1


class SnapshotCorrupt(RuntimeError):
    """Snapshot failed validation: bad magic, checksum, or incompatible
    engine geometry.  Restore paths catch this and fall back to cold start."""


# ---------------------------------------------------------------------------
# array <-> bytes
# ---------------------------------------------------------------------------

def _to_numpy(arr) -> np.ndarray:
    """Materialise a (possibly device) array as a C-contiguous numpy array."""
    out = np.asarray(arr)
    return np.ascontiguousarray(out)


def _encode_array(arr: np.ndarray) -> Tuple[bytes, str, Tuple[int, ...]]:
    """Raw C-order bytes + logical dtype name + shape.

    bfloat16 has no portable numpy file representation, so it travels as its
    uint16 bit pattern; the logical dtype name in the index restores it.
    """
    dtype_name = str(arr.dtype)
    if dtype_name == "bfloat16":
        payload = arr.view(np.uint16).tobytes(order="C")
    else:
        payload = arr.tobytes(order="C")
    return payload, dtype_name, tuple(arr.shape)


def _decode_array(payload: bytes, dtype_name: str, shape) -> np.ndarray:
    shape = tuple(int(s) for s in shape)
    if dtype_name == "bfloat16":
        import ml_dtypes  # ships with jax

        raw = np.frombuffer(payload, dtype=np.uint16).reshape(shape)
        return raw.view(ml_dtypes.bfloat16)
    return np.frombuffer(payload, dtype=np.dtype(dtype_name)).reshape(shape)


# ---------------------------------------------------------------------------
# snapshot object
# ---------------------------------------------------------------------------

@dataclass
class Snapshot:
    """In-memory snapshot: a meta dict plus named sections (JSON-compatible
    dicts or numpy arrays).  `write`/`read` handle the on-disk container."""

    meta: Dict[str, Any]
    sections: Dict[str, Any] = field(default_factory=dict)

    def write(self, path: str) -> Dict[str, Any]:
        index: List[Dict[str, Any]] = []
        payloads: List[bytes] = []
        for name, obj in self.sections.items():
            if isinstance(obj, np.ndarray):
                payload, dtype_name, shape = _encode_array(obj)
                entry = {
                    "name": name,
                    "kind": "array",
                    "dtype": dtype_name,
                    "shape": list(shape),
                }
            else:
                payload = json.dumps(obj, sort_keys=True).encode("utf-8")
                entry = {"name": name, "kind": "json"}
            entry["nbytes"] = len(payload)
            entry["crc32"] = zlib.crc32(payload) & 0xFFFFFFFF
            index.append(entry)
            payloads.append(payload)

        header = json.dumps(
            {"version": SNAPSHOT_VERSION, "meta": self.meta, "index": index},
            sort_keys=True,
        ).encode("utf-8")
        magic = (
            f"{SNAPSHOT_MAGIC} {len(header)} "
            f"{zlib.crc32(header) & 0xFFFFFFFF}\n"
        ).encode("ascii")

        directory = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".snap.", dir=directory)
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(magic)
                f.write(header)
                for payload in payloads:
                    f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)  # atomic on POSIX
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return {
            "path": path,
            "nbytes": len(magic) + len(header) + sum(len(p) for p in payloads),
            "sections": [e["name"] for e in index],
        }

    @classmethod
    def read(cls, path: str) -> "Snapshot":
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError as e:
            raise SnapshotCorrupt(f"cannot read snapshot {path}: {e}") from e

        nl = blob.find(b"\n")
        if nl < 0:
            raise SnapshotCorrupt(f"{path}: no magic line")
        parts = blob[:nl].decode("ascii", errors="replace").split()
        if len(parts) != 3 or parts[0] != SNAPSHOT_MAGIC:
            raise SnapshotCorrupt(f"{path}: bad magic {parts[:1]!r}")
        try:
            header_len, header_crc = int(parts[1]), int(parts[2])
        except ValueError as e:
            raise SnapshotCorrupt(f"{path}: malformed magic line") from e

        header_raw = blob[nl + 1 : nl + 1 + header_len]
        if len(header_raw) != header_len:
            raise SnapshotCorrupt(f"{path}: truncated header")
        if (zlib.crc32(header_raw) & 0xFFFFFFFF) != header_crc:
            raise SnapshotCorrupt(f"{path}: header checksum mismatch")
        header = json.loads(header_raw.decode("utf-8"))
        if header.get("version") != SNAPSHOT_VERSION:
            raise SnapshotCorrupt(
                f"{path}: unsupported snapshot version {header.get('version')}"
            )

        sections: Dict[str, Any] = {}
        off = nl + 1 + header_len
        for entry in header["index"]:
            n = int(entry["nbytes"])
            payload = blob[off : off + n]
            if len(payload) != n:
                raise SnapshotCorrupt(
                    f"{path}: truncated section {entry['name']!r}"
                )
            if (zlib.crc32(payload) & 0xFFFFFFFF) != int(entry["crc32"]):
                raise SnapshotCorrupt(
                    f"{path}: checksum mismatch in section {entry['name']!r}"
                )
            if entry["kind"] == "array":
                sections[entry["name"]] = _decode_array(
                    payload, entry["dtype"], entry["shape"]
                )
            else:
                sections[entry["name"]] = json.loads(payload.decode("utf-8"))
            off += n
        return cls(meta=header["meta"], sections=sections)


# ---------------------------------------------------------------------------
# engine -> snapshot
# ---------------------------------------------------------------------------

_REQUEST_FIELDS = (
    "req_id", "prompt", "max_new", "temperature", "state", "tokens",
    "n_generated", "n_cached", "n_prefix_hit", "n_prefilled", "epoch",
    "n_preemptions", "t_submit", "t_admit", "t_first_token", "t_last_token",
    "t_finish", "finish_reason", "deadline_s", "ttft_budget_s",
    "ttft_observed",
)


def _pack_request(req) -> Dict[str, Any]:
    rec = {}
    for name in _REQUEST_FIELDS:
        v = getattr(req, name)
        if isinstance(v, (list, np.ndarray)):
            v = [int(t) for t in v]
        elif isinstance(v, np.integer):
            v = int(v)
        elif isinstance(v, np.floating):
            v = float(v)
        rec[name] = v
    return rec


def _unpack_request(rec: Dict[str, Any], request_cls):
    req = request_cls(
        req_id=int(rec["req_id"]),
        prompt=np.asarray(rec["prompt"], np.int32),
        max_new=int(rec["max_new"]),
        temperature=float(rec["temperature"]),
    )
    for name in _REQUEST_FIELDS:
        if name in ("req_id", "prompt", "max_new", "temperature"):
            continue
        v = rec[name]
        if name == "tokens":
            v = [int(t) for t in v]
        setattr(req, name, v)
    return req


def engine_fingerprint(engine) -> Dict[str, Any]:
    """Geometry a snapshot must match to be applied to an engine."""
    cfg = engine.cfg
    return {
        "n_layers": int(cfg.n_layers),
        "n_kv_heads": int(cfg.n_kv_heads),
        "head_dim": int(cfg.head_dim_),
        "vocab_size": int(cfg.vocab_size),
        "block_size": int(engine.pool.block_size),
        "num_blocks": int(engine.pool.num_blocks),
        "kv_dtype": engine.pool.kv_dtype,
        "quantized": bool(engine.pool.quantized),
        "max_batch": int(engine.sched.max_batch),
        "max_len": int(engine.sched.max_len),
        "prefix_cache": engine.prefix_cache is not None,
    }


def snapshot_state(engine) -> Snapshot:
    """Capture the full serving state of a (drained-pipeline) engine.

    Drains the async sampling pipeline first so every generated token is
    host-visible — the snapshot then has no in-flight device work to lose.
    """
    engine.drain()
    pool = engine.pool
    cache = engine.prefix_cache
    sched = engine.sched

    meta = {
        "fingerprint": engine_fingerprint(engine),
        "steps": int(engine.metrics.steps),
        "evict_policy": getattr(cache, "evict_policy", None) if cache else None,
    }
    sections: Dict[str, Any] = {}

    # --- pool arrays -------------------------------------------------------
    sections["pool.k"] = _to_numpy(pool.k)
    sections["pool.v"] = _to_numpy(pool.v)
    if pool.quantized:
        sections["pool.k_scale"] = _to_numpy(pool.k_scale)
        sections["pool.v_scale"] = _to_numpy(pool.v_scale)

    sections["pool_meta"] = {
        "free": [int(b) for b in pool._free],
        "ref": [int(r) for r in np.asarray(pool._ref)],
        "tables": {str(rid): [int(b) for b in blocks]
                   for rid, blocks in pool._tables.items()},
        "stats": asdict(pool.stats),
        "kv_dtype": pool.kv_dtype,
        "quantized": bool(pool.quantized),
    }

    # --- radix tree --------------------------------------------------------
    if cache is not None:
        nodes: List[Dict[str, Any]] = []
        ids: Dict[int, int] = {id(cache.root): 0}
        # parent-before-child order so restore can wire parents in one pass
        stack = [cache.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                ids[id(child)] = len(ids)
                nodes.append({
                    "id": ids[id(child)],
                    "parent": ids[id(node)],
                    "key": [int(t) for t in child.key],
                    "block": int(child.block),
                    "ref": int(child.ref),
                    "stamp": int(child.stamp),
                    "seq": int(child.seq),
                })
                stack.append(child)
        sections["radix"] = {
            "nodes": nodes,
            # purge() detaches nodes other requests still pin (their
            # pins unwind at release, which never touches tree
            # structure).  A detached node is unreachable — no future
            # match or eviction sees it — so its pin carries no state
            # worth restoring: keep only pins on live tree nodes
            "held": {str(rid): [ids[id(n)] for n in pins
                                if id(n) in ids]
                     for rid, pins in cache._held.items()},
            "cursor": {str(rid): [ids[id(node)], int(skip)]
                       for rid, (node, skip) in cache._cursor.items()},
            "clock": int(cache._clock),
            "stats": asdict(cache.stats),
        }

    # --- scheduler ---------------------------------------------------------
    sections["sched"] = {
        "waiting": [_pack_request(r) for r in sched.waiting],
        "running": [_pack_request(r) for r in sched.running],
        "finished": {str(rid): _pack_request(r)
                     for rid, r in sched.finished.items()},
        "reserved": {str(rid): int(n) for rid, n in sched._reserved.items()},
        "next_id": int(sched._next_id),
        "n_preemptions": int(sched.n_preemptions),
        "tokens_discarded": int(sched.tokens_discarded),
    }

    # --- engine ------------------------------------------------------------
    # a row whose request has already left `running` (finished and popped
    # by the caller) is vacated here, exactly as `_sync_rows` would on the
    # next step — the restored scheduler sections no longer carry it
    running_ids = {id(r) for r in sched.running}
    sections["engine"] = {
        "metrics": asdict(engine.metrics),
        "rows": [int(r.req_id) if (r is not None and id(r) in running_ids)
                 else None for r in engine._rows],
        "vec": [int(t) for t in np.asarray(engine._vec)],
        "key": [int(x) for x in np.asarray(engine._key, dtype=np.uint32)],
        "fault_pressure_blocks": int(
            getattr(engine, "_fault_pressure_blocks", 0)),
    }
    return Snapshot(meta=meta, sections=sections)


def write_snapshot(engine, path: str) -> Dict[str, Any]:
    """snapshot_state + atomic write; returns {path, nbytes, sections}."""
    return snapshot_state(engine).write(path)


# ---------------------------------------------------------------------------
# snapshot -> engine
# ---------------------------------------------------------------------------

def apply_snapshot(engine, snap: Snapshot, fsck: bool = True) -> None:
    """Rebuild a freshly-constructed, warmed engine's full state in place.

    The engine must have matching geometry (checked against the snapshot
    fingerprint) and no live requests.  On success the engine continues
    exactly where the snapshotted one stopped: same pools, same tree, same
    queues, same decode rows, same PRNG stream.  With ``fsck=True`` (the
    default) `check_invariants` runs on the restored state and any violation
    propagates — callers treat it like a checksum failure and fall back to
    cold start.
    """
    import jax
    import jax.numpy as jnp

    from .invariants import check_invariants

    fp_engine = engine_fingerprint(engine)
    fp_snap = snap.meta.get("fingerprint", {})
    if fp_engine != fp_snap:
        diff = {k: (fp_snap.get(k), fp_engine.get(k))
                for k in set(fp_snap) | set(fp_engine)
                if fp_snap.get(k) != fp_engine.get(k)}
        raise SnapshotCorrupt(f"fingerprint mismatch (snap, engine): {diff}")
    if engine.sched.running or engine.sched.waiting:
        raise RuntimeError("apply_snapshot requires an idle engine")

    pool = engine.pool
    cache = engine.prefix_cache
    sched = engine.sched

    # drop the engine's own tree FIRST: reset() releases its blocks back
    # into the pool, which must not touch the restored free list/refcounts
    if cache is not None:
        cache.reset()

    # --- pool --------------------------------------------------------------
    pm = snap.sections["pool_meta"]
    pool.k = jnp.asarray(snap.sections["pool.k"])
    pool.v = jnp.asarray(snap.sections["pool.v"])
    if pool.quantized:
        pool.k_scale = jnp.asarray(snap.sections["pool.k_scale"])
        pool.v_scale = jnp.asarray(snap.sections["pool.v_scale"])
    pool._free = [int(b) for b in pm["free"]]
    pool._ref = np.asarray(pm["ref"], dtype=np.int32)
    pool._tables = {int(rid): [int(b) for b in blocks]
                    for rid, blocks in pm["tables"].items()}
    for name, value in pm["stats"].items():
        setattr(pool.stats, name, value)

    # --- radix tree --------------------------------------------------------
    if cache is not None:
        rx = snap.sections.get("radix")
        if rx is None:
            raise SnapshotCorrupt("engine has a prefix cache but snapshot "
                                  "carries no radix section")
        by_id = {0: cache.root}
        node_cls = type(cache.root)
        for rec in rx["nodes"]:
            parent = by_id[int(rec["parent"])]
            node = node_cls(
                key=tuple(int(t) for t in rec["key"]),
                block=int(rec["block"]),
                parent=parent,
                seq=int(rec["seq"]),
            )
            node.ref = int(rec["ref"])
            node.stamp = int(rec["stamp"])
            node.seq = int(rec["seq"])
            parent.children[node.key] = node
            by_id[int(rec["id"])] = node
        cache._held = {int(rid): [by_id[int(i)] for i in pins]
                       for rid, pins in rx["held"].items()}
        cache._cursor = {int(rid): (by_id[int(i)], int(skip))
                         for rid, (i, skip) in rx["cursor"].items()}
        cache._clock = int(rx["clock"])
        for name, value in rx["stats"].items():
            setattr(cache.stats, name, value)

    # --- scheduler ---------------------------------------------------------
    sc = snap.sections["sched"]
    request_cls = type(sched).__module__  # resolved below via import
    from .scheduler import Request as request_cls  # noqa: F811

    sched.waiting.clear()
    sched.running.clear()
    sched.finished.clear()
    by_rid: Dict[int, Any] = {}
    for rec in sc["waiting"]:
        req = _unpack_request(rec, request_cls)
        sched.waiting.append(req)
        by_rid[req.req_id] = req
    for rec in sc["running"]:
        req = _unpack_request(rec, request_cls)
        sched.running.append(req)
        by_rid[req.req_id] = req
    for rid, rec in sc["finished"].items():
        req = _unpack_request(rec, request_cls)
        sched.finished[int(rid)] = req
        by_rid[req.req_id] = req
    sched._reserved = {int(rid): int(n) for rid, n in sc["reserved"].items()}
    sched._next_id = int(sc["next_id"])
    sched.n_preemptions = int(sc["n_preemptions"])
    sched.tokens_discarded = int(sc["tokens_discarded"])

    # --- engine ------------------------------------------------------------
    eg = snap.sections["engine"]
    engine.metrics = engine._fresh_metrics()
    for name, value in eg["metrics"].items():
        if hasattr(engine.metrics, name):
            setattr(engine.metrics, name, value)
    # decode rows must be the *same objects* as sched.running entries:
    # _sync_rows vacates rows by id() membership.
    engine._rows = [None if rid is None else by_rid[int(rid)]
                    for rid in eg["rows"]]
    engine._vec = jnp.asarray(eg["vec"], dtype=jnp.int32)
    engine._key = jnp.asarray(np.asarray(eg["key"], dtype=np.uint32))
    engine._fault_pressure_blocks = int(eg.get("fault_pressure_blocks", 0))
    engine._pending = []

    if fsck:
        check_invariants(pool, cache)


def requeue_inflight(engine) -> List[Dict[str, Any]]:
    """Convert a restored engine's in-flight requests into resubmit specs.

    Cross-process resume cannot continue half-done device work, but it can
    replay it exactly: each waiting/running request becomes a
    ``[prompt ‖ emitted]`` resubmission spec (the PR 9 recompute contract),
    and its blocks go back to the pool/tree — generated-token KV is first
    published into the radix tree so the resubmission re-hits it as warm
    prefix instead of recomputing prefill from scratch.
    """
    sched = engine.sched
    cache = engine.prefix_cache
    pool = engine.pool
    specs: List[Dict[str, Any]] = []

    for req in list(sched.running):
        # keep the KV produced so far warm: publish [prompt ‖ generated]
        # into the tree before the table is released
        try:
            sched._publish_generated(req)
        except Exception:
            pass
        specs.append({
            "rid": int(req.req_id),
            "prompt": [int(t) for t in req.prompt],
            "tokens": [int(t) for t in req.tokens],
            "max_new": int(req.max_new),
            "temperature": float(req.temperature),
        })
        sched._release(req)
        sched._reserved.pop(req.req_id, None)
    sched.running.clear()

    for req in list(sched.waiting):
        specs.append({
            "rid": int(req.req_id),
            "prompt": [int(t) for t in req.prompt],
            "tokens": [int(t) for t in req.tokens],
            "max_new": int(req.max_new),
            "temperature": float(req.temperature),
        })
        if pool._tables.get(req.req_id):
            if cache is not None:
                cache.release(req.req_id)
            else:
                pool.free(req.req_id)
        sched._reserved.pop(req.req_id, None)
    sched.waiting.clear()
    sched.finished.clear()

    import jax.numpy as jnp

    engine._rows = [None] * sched.max_batch
    engine._vec = jnp.zeros((sched.max_batch,), jnp.int32)
    engine._pending = []
    specs.sort(key=lambda s: s["rid"])
    return specs


def restore_engine(
    engine_factory: Callable[[], Any],
    snapshot_path: Optional[str],
    fsck: bool = True,
    requeue: bool = True,
) -> Tuple[Any, List[Dict[str, Any]], Dict[str, Any]]:
    """Build an engine from a snapshot, falling back to cold start.

    Returns ``(engine, specs, info)`` where ``specs`` are resubmission specs
    for requests that were in flight at snapshot time (empty when
    ``requeue=False`` or on cold start) and ``info`` records which rung of
    the recovery ladder ran: ``{"mode": "warm"|"cold", "reason": ...}``.

    The factory must return a constructed+warmed engine; it is called once
    for the warm attempt and once more if that attempt fails fsck, so a
    poisoned snapshot can never leak state into the cold fallback.
    """
    from .invariants import InvariantViolation

    if snapshot_path and os.path.exists(snapshot_path):
        engine = engine_factory()
        try:
            snap = Snapshot.read(snapshot_path)
            apply_snapshot(engine, snap, fsck=fsck)
            specs = requeue_inflight(engine) if requeue else []
            return engine, specs, {"mode": "warm", "reason": "snapshot ok"}
        except (SnapshotCorrupt, InvariantViolation) as e:
            reason = f"{type(e).__name__}: {e}"
        engine = engine_factory()  # discard poisoned partial state
        return engine, [], {"mode": "cold", "reason": reason}

    engine = engine_factory()
    reason = "no snapshot" if not snapshot_path else "snapshot missing"
    return engine, [], {"mode": "cold", "reason": reason}
