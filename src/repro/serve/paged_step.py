"""Model step functions over the paged KV cache (attention-family LMs).

These mirror ``models/lm.py``'s prefill/decode pair but speak the block-pool
layout instead of a contiguous per-request cache:

* ``paged_prefill``     — full-prompt forward (prompts right-padded to a
  block multiple; causality keeps pad junk out of the real tokens) returning
  the true-last-token logits plus the per-layer K/V to scatter into the pool.
* ``scatter_prefill``   — place a prefilled request's K/V into its allocated
  physical blocks (one fused device scatter).
* ``paged_prefill_suffix`` — offset-aware prefill for the radix prefix
  cache: only the *uncached* prompt suffix runs through the model (absolute
  positions ``pos0..``), with each layer's attention reading the cached
  prefix K/V straight out of the pool through the request's block table.
  One shot: the whole suffix is a single dense attention, quadratic in the
  suffix and linear in the prefix — fine for chat-sized prompts, the wrong
  shape for long documents.
* ``scatter_prefill_offset`` — place suffix K/V rows at arbitrary
  (block, row) coordinates: the suffix may start mid-block when a matched
  partial tail block was extended copy-on-write.
* ``paged_prefill_chunked`` — one *chunk* of a long prompt (engine-driven
  chunked prefill): per layer the chunk's K/V rows are scattered into the
  pool first, then the chunk's queries attend the pool directly through
  the block table (``kernels/flash_prefill_paged``) — cached prefix,
  earlier chunks, and the chunk's own causal triangle are all one KV
  source, so nothing is gathered-and-concatenated and no score matrix ever
  exceeds (chunk, prefix+chunk). The online-normalization state is carried
  across KV tiles inside the kernel; across chunk boundaries no state is
  handed over at all — earlier chunks' contribution is pool-resident and
  the Softermax recurrence is order-free, so re-attending it online is
  exact.
* ``paged_decode_step`` — one token for the whole running batch: per layer,
  write the new K/V row through the block table, then run paged Softermax
  decode attention over the pool. Inactive batch slots carry block table 0
  (the pool's garbage block) and length 0, so their writes and reads are
  harmless and their logits are ignored by the engine.

Attention math is identical to the contiguous path (same Unnormed-Softmax-
Unit recurrence): on TPU / under ``cfg.interpret_kernels`` the Pallas
``flash_decode_paged`` kernel runs; elsewhere a pure-JAX gather fallback
keeps CPU tests fast.

**Int8 pools (quantize-on-scatter).** Every step function takes the pool's
optional per-row scale tensors (``k_scale``/``v_scale``; ``None`` for
bf16/f32 pools — the pool dtype, static under jit, selects the path).
Writers quantize rows symmetrically per (head, token) at the moment they
scatter (``attention_apply``'s projections stay full precision); readers
dequantize at gather — fused into the Pallas kernels on TPU, post-gather
in the refs — and accumulate in fp32, so the only precision loss is the
int8 rounding of the stored K/V rows, the same contract as the dense
``models/attention.py`` int8 decode branch. Functions that update the pool
return the new scale tensors after the new pools (callers unpack by mode).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.numerics import NEG_INF
from repro.kernels.flash_decode_paged import flash_decode_paged_op
from repro.kernels.flash_decode_paged.ref import gather_kv_dequant
from repro.kernels.flash_prefill_paged import flash_prefill_paged_op
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.layers import embed, logits, mlp, rmsnorm, rope
from repro.models.lm import maybe_cast_params
from repro.parallel.sharding import shard_act


def check_paged_support(cfg: ModelConfig) -> None:
    """Paged serving covers the GQA attention families; everything else
    stays on the static engine."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"paged serving: unsupported family {cfg.family!r}")
    if cfg.mla is not None or cfg.ssm is not None:
        raise ValueError("paged serving: MLA/SSM caches not supported")
    if cfg.moe.first_dense:
        raise ValueError("paged serving: leading dense head blocks "
                         "not supported")
    if cfg.window:
        raise ValueError("paged serving: sliding-window archs not supported")


def table_width_bucket(need: int, *, nb_max: Optional[int] = None,
                       chunk_blocks: Optional[int] = None) -> int:
    """THE block-table width policy for the whole serving stack — engine
    decode/suffix tables, chunked-prefill covers, warmup shape enumeration,
    and the benches' engine-faithful replay all quantize through this one
    helper, so jit bucket counts stay bounded and the split-ref table
    contract lives in one place.

    * ``chunk_blocks`` set — chunked-prefill cover policy: round ``need``
      up to a multiple of the chunk's own block count (``nb_max`` is
      ignored — a cover never exceeds the request's own table). Bucket
      count stays bounded (max-table / chunk_blocks of them) AND the pad
      never exceeds the masked tail region the CPU split oracle assumes —
      this is exactly the ``paged_prefill_chunked`` table contract
      (``paged_prefill_split_ref``'s CONTRACT note), so changing the
      policy here is changing the contract.
    * otherwise — pow2 policy (decode and one-shot suffix tables): next
      power of two covering ``need``, clamped to ``nb_max`` (few buckets
      instead of every width; the clamp never truncates — any in-range
      table fits in ``nb_max`` blocks).
    """
    if chunk_blocks is not None:
        # a 0 here would silently fall through to the pow2 policy and
        # break the split-ref contract — fail loudly instead
        if chunk_blocks < 1:
            raise ValueError(f"chunk_blocks must be >= 1, "
                             f"got {chunk_blocks}")
        return -(-need // chunk_blocks) * chunk_blocks
    w = 1
    while w < need:
        w *= 2
    if nb_max is not None:
        w = max(min(w, nb_max), need)
    return w


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def _fake_quant_kv(t: jax.Array) -> jax.Array:
    """Round-trip ``t`` through the pool's int8 representation. Re-
    quantizing the result reproduces the exact same int8 codes (the amax
    row maps back to ±127 and the scale round-trips within ~2^-24, far
    inside round-to-nearest's 0.5 margin), so a prefill that attends
    fake-quantized rows sees bit-identical values to every later reader
    that dequantizes the scattered block — chunked prefill, decode, and
    prefix-cache rehits all agree on what a cached token "is"."""
    q8, sc = attn_mod.quantize_kv(t)
    return attn_mod.dequantize_kv(q8, sc, t.dtype)


def paged_prefill(
    params,
    tokens: jax.Array,       # (B, Sp) prompts right-padded to a block multiple
    last_pos: jax.Array,     # (B,) int32 index of the true last prompt token
    cfg: ModelConfig,
    kv_quantize: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (true-last-token logits (B, V), k, v (L, B, Hkv, Sp, Dh)).

    ``kv_quantize`` (int8 pools) round-trips each layer's K/V through the
    int8 grid *before* the in-prompt attention, so the prompt attends the
    same values the pool will store (the scatter's re-quantization is
    code-exact on fake-quantized rows) — without it, a chunked re-prefill
    of the same prompt would see slightly different KV than the one-shot
    path computed. The quantized branch runs XLA-level chunked softermax
    attention directly (flash/ring impl selection doesn't apply — the KV
    it would attend is no longer what ``attention_apply`` projects)."""
    B, Sp = tokens.shape
    params = maybe_cast_params(params, cfg)
    dh = cfg.head_dim_
    premult, intmax = attn_mod._mode(cfg)
    positions = jnp.broadcast_to(jnp.arange(Sp, dtype=jnp.int32), (B, Sp))
    x = embed(params["embed"], tokens, cfg)

    def body(x, bp):
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        if kv_quantize:
            q, k, v = attn_mod._project_qkv(bp["mixer"], h, cfg, positions)
            k = _fake_quant_kv(k)
            v = _fake_quant_kv(v)
            q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
            q = shard_act(q, ("batch", "act_heads", "seq", "head_dim"))
            k = shard_act(k, ("batch", "act_heads", "seq", "head_dim"))
            v = shard_act(v, ("batch", "act_heads", "seq", "head_dim"))
            o = attn_mod.chunked_attention(q, k, v, causal=True,
                                           intmax=intmax,
                                           chunk=cfg.attention_chunk)
            y = attn_mod._out_proj(bp["mixer"], o, cfg)
        else:
            y, k, v = attn_mod.attention_apply(
                bp["mixer"], h, cfg, positions=positions, causal=True,
                return_kv=True)
        x = x + y
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg)
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        x = shard_act(x + f, ("batch", "seq", "act_embed"))
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x_last = jnp.take_along_axis(
        x, last_pos[:, None, None].astype(jnp.int32), axis=1)  # (B, 1, d)
    lg = logits(params["embed"], x_last, cfg)[:, 0]
    return lg, ks, vs


def _audit_attention(q, k, v, intmax):
    """Dense causal softermax in closed form: same exp2 / running-IntMax
    recurrence as the serving kernels (order-free, so the closed form is
    exact), returning the per-row IntMax and raw scores for the numerics
    monitors alongside the output."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    causal = jnp.tril(jnp.ones((Sq, Sq), bool))
    s = jnp.where(causal[None, None, None], s, NEG_INF)
    m = jnp.max(jnp.ceil(s) if intmax else s, axis=-1, keepdims=True)
    p = jnp.exp2(s - m)
    d = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", (p / d).astype(v.dtype), v)
    return (o.reshape(B, Hq, Sq, D).astype(q.dtype), m[..., 0],
            s, causal[None, None, None])


def paged_prefill_audit(
    params,
    tokens: jax.Array,       # (B, Sp) probe prompt (no padding expected)
    last_pos: jax.Array,     # (B,) int32 index of the true last token
    cfg: ModelConfig,
):
    """Lockstep full-precision vs int8-fake-quant prefill of one prompt —
    the online numerics monitor behind ``Telemetry.numerics_probe``.

    One scan carries *both* residual streams: the reference branch attends
    the exact projected K/V, the quantized branch round-trips each layer's
    K/V through the pool's int8 grid first (``_fake_quant_kv`` — bit-
    identical to what a later reader dequantizes from the pool), and both
    use the same dense Softermax closed form, so the returned logit delta
    isolates exactly the int8 storage error PR 4's offline bench bounds at
    ≤ 0.1. Along the way the quantized branch's scores and K/V feed the
    hardware-margin monitors:

    * ``score_intmax_max`` / ``intmax_overflow_rows`` — the running-IntMax
      values every Softermax row tracks vs the paper's Q(6,2) LocalMax
      format: an overflow row is one whose IntMax a real accumulator
      would saturate.
    * ``score_inp_clip_vals`` — causally-valid score entries outside the
      Q(6,2) Inp format (pre-normalization saturation).
    * ``kv_amax_max`` / ``kv_scale_sat_rows`` — per-(head, token) K/V amax
      vs a per-layer 99.999%-percentile static scale: rows a statically
      calibrated int8 pool (as opposed to our per-row scales) would clip.

    Returns ``(lg_ref, lg_q, stats)`` with logits (B, V-padded) and stats
    a dict of scalar jax arrays (summed/maxed over layers).
    """
    from repro.core.quant import (DEFAULT_BITWIDTHS, percentile_scale,
                                  qformat_clip_count)

    B, Sp = tokens.shape
    params = maybe_cast_params(params, cfg)
    dh = cfg.head_dim_
    premult, intmax = attn_mod._mode(cfg)
    qscale = premult * dh ** -0.5
    positions = jnp.broadcast_to(jnp.arange(Sp, dtype=jnp.int32), (B, Sp))
    x0 = embed(params["embed"], tokens, cfg)
    fmt_max = DEFAULT_BITWIDTHS.localmax
    fmt_inp = DEFAULT_BITWIDTHS.inp

    def ffn(bp, x):
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg)
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        return x + f

    def body(carry, bp):
        x_ref, x_q = carry
        # reference branch: exact K/V
        h = rmsnorm(bp["ln1"], x_ref, cfg.norm_eps)
        q, k, v = attn_mod._project_qkv(bp["mixer"], h, cfg, positions)
        o, _, _, _ = _audit_attention(q * jnp.asarray(qscale, q.dtype),
                                      k, v, intmax)
        x_ref = ffn(bp, x_ref + attn_mod._out_proj(bp["mixer"], o, cfg))
        # quantized branch: K/V through the pool's int8 grid
        h = rmsnorm(bp["ln1"], x_q, cfg.norm_eps)
        q, k, v = attn_mod._project_qkv(bp["mixer"], h, cfg, positions)
        amax_k = jnp.max(jnp.abs(k), axis=-1)         # (B, Hkv, Sp)
        amax_v = jnp.max(jnp.abs(v), axis=-1)
        kv_amax = jnp.maximum(jnp.max(amax_k), jnp.max(amax_v))
        sat = (jnp.sum(amax_k > 127.0 * percentile_scale(k)) +
               jnp.sum(amax_v > 127.0 * percentile_scale(v)))
        kq = _fake_quant_kv(k)
        vq = _fake_quant_kv(v)
        o, m, s, valid = _audit_attention(
            q * jnp.asarray(qscale, q.dtype), kq, vq, intmax)
        x_q = ffn(bp, x_q + attn_mod._out_proj(bp["mixer"], o, cfg))
        stats = (jnp.max(m),
                 jnp.sum(m > fmt_max.max_value),
                 qformat_clip_count(s, fmt_inp,
                                    where=jnp.broadcast_to(valid, s.shape)),
                 kv_amax, sat)
        return (x_ref, x_q), stats

    (x_ref, x_q), (mx, ovf, clip, amax, sat) = jax.lax.scan(
        body, (x0, x0), params["blocks"])

    def head(x):
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        x_last = jnp.take_along_axis(
            x, last_pos[:, None, None].astype(jnp.int32), axis=1)
        return logits(params["embed"], x_last, cfg)[:, 0]

    stats = {
        "score_intmax_max": jnp.max(mx),
        "intmax_overflow_rows": jnp.sum(ovf),
        "score_inp_clip_vals": jnp.sum(clip),
        "kv_amax_max": jnp.max(amax),
        "kv_scale_sat_rows": jnp.sum(sat),
    }
    return head(x_ref), head(x_q), stats


def scatter_prefill(
    k_pool: jax.Array,       # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    ks: jax.Array,           # (L, 1, Hkv, Sp, Dh) from paged_prefill (B=1)
    vs: jax.Array,
    block_ids: jax.Array,    # (nb,) int32 physical blocks, nb*BS == Sp
    k_scale: jax.Array = None,   # (L, N, Hkv, BS) f32 scale pools (int8)
    v_scale: jax.Array = None,
):
    """Returns (k_pool, v_pool) — or (k_pool, v_pool, k_scale, v_scale)
    when the pool is int8: rows are quantized per (layer, head, token) at
    scatter time and their scales land in the sibling scale pools."""
    L, _, Hkv, Sp, Dh = ks.shape
    BS = k_pool.shape[3]
    nb = Sp // BS

    def place(pool, seq):
        blocks = seq[:, 0].reshape(L, Hkv, nb, BS, Dh)
        blocks = jnp.moveaxis(blocks, 2, 1)          # (L, nb, Hkv, BS, Dh)
        return pool.at[:, block_ids].set(blocks.astype(pool.dtype))

    def place_scale(pool, sc):                       # sc (L, 1, Hkv, Sp)
        blocks = jnp.moveaxis(sc[:, 0].reshape(L, Hkv, nb, BS), 2, 1)
        return pool.at[:, block_ids].set(blocks)

    if k_pool.dtype != jnp.int8:
        return place(k_pool, ks), place(v_pool, vs)
    kq, ksc = attn_mod.quantize_kv(ks)
    vq, vsc = attn_mod.quantize_kv(vs)
    return (place(k_pool, kq), place(v_pool, vq),
            place_scale(k_scale, ksc), place_scale(v_scale, vsc))


# ---------------------------------------------------------------------------
# Offset prefill (radix prefix cache: compute only the uncached suffix)
# ---------------------------------------------------------------------------


def _suffix_attention(q, k_pre, v_pre, k_suf, v_suf, pre_valid, q_pos,
                      intmax):
    """Dense softermax attention of suffix queries over [cached prefix ‖
    in-flight suffix].

    q (B, Hq, Sq, D) pre-scaled; k_pre/v_pre (B, Hkv, Sk, D) gathered from
    the pool (rows >= prefix_len are garbage — masked by ``pre_valid``);
    k_suf/v_suf (B, Hkv, Sq, D); q_pos (B, Sq) absolute positions. Same
    exp2 / running-IntMax math as the chunked prefill and the paged decode
    kernel, in closed form (one prompt, modest lengths)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k_pre.shape
    group = Hq // Hkv
    k = jnp.concatenate([k_pre, k_suf], axis=2)
    v = jnp.concatenate([v_pre, v_suf], axis=2)
    qg = q.reshape(B, Hkv, group, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    # prefix columns: valid rows are strictly before every suffix query;
    # suffix columns: causal within the suffix (pad rows sit at the end,
    # after every true position, so causality keeps their junk inert).
    valid_pre = jnp.broadcast_to(pre_valid[:, None, :], (B, Sq, Sk))
    valid_suf = q_pos[:, :, None] >= q_pos[:, None, :]
    valid = jnp.concatenate([valid_pre, valid_suf], axis=2)   # (B, Sq, Sk+Sq)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(jnp.ceil(s) if intmax else s, axis=-1, keepdims=True)
    p = jnp.exp2(s - m)
    d = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(d > 0, p / jnp.where(d > 0, d, 1.0), 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def paged_prefill_suffix(
    params,
    tokens: jax.Array,        # (B, Sp) uncached suffix, right-padded
    pos0: jax.Array,          # () int32 absolute position of tokens[:, 0]
    last_rel: jax.Array,      # (B,) index of the true last token in tokens
    k_pool: jax.Array,        # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    prefix_table: jax.Array,  # (B, W) physical blocks of the cached prefix
    prefix_len: jax.Array,    # (B,) cached tokens (pad rows masked out)
    cfg: ModelConfig,
    k_scale: jax.Array = None,   # (L, N, Hkv, BS) f32 scale pools (int8)
    v_scale: jax.Array = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill only the uncached suffix of a prompt whose first ``pos0``
    tokens are already resident in the pool (radix prefix-cache hit).

    Per layer the suffix Q/K/V are computed at absolute positions
    ``pos0 + i`` (RoPE stays consistent with the cold path) and attention
    runs over the cached prefix — gathered from the pool through
    ``prefix_table``, dequantized when the pool is int8 — concatenated with
    the in-flight suffix. Returns (true-last-token logits (B, V), ks, vs
    (L, B, Hkv, Sp, Dh)); the caller scatters ks/vs with
    ``scatter_prefill_offset`` (which quantizes them for int8 pools).
    ``pos0 == 0`` with an empty prefix degenerates to ``paged_prefill``'s
    math.
    """
    B, Sp = tokens.shape
    params = maybe_cast_params(params, cfg)
    dh = cfg.head_dim_
    premult, intmax = attn_mod._mode(cfg)
    quantized = k_pool.dtype == jnp.int8
    positions = pos0 + jnp.broadcast_to(jnp.arange(Sp, dtype=jnp.int32),
                                        (B, Sp))
    x = embed(params["embed"], tokens, cfg)
    W = prefix_table.shape[1]
    BS = k_pool.shape[3]
    pre_valid = jnp.arange(W * BS, dtype=jnp.int32)[None, :] < \
        prefix_len[:, None]                                   # (B, W*BS)

    def body(x, xs):
        if quantized:
            bp, kp_l, vp_l, ksc_l, vsc_l = xs
        else:
            bp, kp_l, vp_l = xs
            ksc_l = vsc_l = None
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        q, k, v = attn_mod._project_qkv(bp["mixer"], h, cfg, positions)
        if quantized:
            # the in-flight suffix must attend the same values the pool
            # will store (see _fake_quant_kv) — the cached prefix is
            # already the dequantized pool rows
            k = _fake_quant_kv(k)
            v = _fake_quant_kv(v)
        q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
        k_pre = gather_kv_dequant(kp_l, ksc_l, prefix_table).astype(k.dtype)
        v_pre = gather_kv_dequant(vp_l, vsc_l, prefix_table).astype(v.dtype)
        o = _suffix_attention(q, k_pre, v_pre, k, v, pre_valid, positions,
                              intmax)
        y = attn_mod._out_proj(bp["mixer"], o, cfg)
        x = x + y
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg)
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        x = shard_act(x + f, ("batch", "seq", "act_embed"))
        return x, (k, v)

    xs = (params["blocks"], k_pool, v_pool, k_scale, v_scale) if quantized \
        else (params["blocks"], k_pool, v_pool)
    x, (ks, vs) = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x_last = jnp.take_along_axis(
        x, last_rel[:, None, None].astype(jnp.int32), axis=1)  # (B, 1, d)
    lg = logits(params["embed"], x_last, cfg)[:, 0]
    return lg, ks, vs


def scatter_prefill_offset(
    k_pool: jax.Array,       # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    ks: jax.Array,           # (L, 1, Hkv, Sp, Dh) from paged_prefill_suffix
    vs: jax.Array,
    blk: jax.Array,          # (Sp,) int32 physical block per suffix row
    off: jax.Array,          # (Sp,) int32 row within that block
    k_scale: jax.Array = None,   # (L, N, Hkv, BS) f32 scale pools (int8)
    v_scale: jax.Array = None,
):
    """Row-granular scatter for an offset prefill: suffix row ``i`` lands at
    ``pool[:, blk[i], :, off[i], :]``. The suffix may start mid-block (a
    copy-on-write tail continues where the cached rows end), so unlike
    ``scatter_prefill`` the destination is not whole blocks; the caller
    routes padding rows to garbage block 0. Int8 pools quantize rows here
    and return the updated scale pools as well."""
    L, _, Hkv, Sp, Dh = ks.shape
    h = jnp.arange(Hkv)

    def place(pool, seq):
        rows = jnp.swapaxes(seq[:, 0], 1, 2)          # (L, Sp, Hkv, Dh)
        return pool.at[:, blk[:, None], h[None, :], off[:, None], :].set(
            rows.astype(pool.dtype))

    def place_scale(pool, sc):                        # sc (L, 1, Hkv, Sp)
        rows = jnp.swapaxes(sc[:, 0], 1, 2)           # (L, Sp, Hkv)
        return pool.at[:, blk[:, None], h[None, :], off[:, None]].set(rows)

    if k_pool.dtype != jnp.int8:
        return place(k_pool, ks), place(v_pool, vs)
    kq, ksc = attn_mod.quantize_kv(ks)
    vq, vsc = attn_mod.quantize_kv(vs)
    return (place(k_pool, kq), place(v_pool, vq),
            place_scale(k_scale, ksc), place_scale(v_scale, vsc))


# ---------------------------------------------------------------------------
# Chunked prefill (flash-prefill kernel over the block table)
# ---------------------------------------------------------------------------


def _chunk_attention(q, k_pool_l, v_pool_l, table, pos0, cfg, intmax,
                     ksc_l=None, vsc_l=None, kv_tile_blocks=1):
    """Chunk queries over block-table-resident KV through the one shared
    dispatcher: Pallas kernel on TPU / under ``cfg.interpret_kernels``;
    elsewhere the pure-JAX split oracle, which skips the causal mask on
    the provably-valid prefix bulk. Passing ``split_tail_blocks`` is safe
    here because ``paged_prefill_chunked`` requires an exact-cover or
    chunk-quantized table (see the contract in its signature)."""
    BS = k_pool_l.shape[2]
    tail = 2 * (-(-q.shape[2] // BS)) + 1
    return flash_prefill_paged_op(q, k_pool_l, v_pool_l, table, pos0,
                                  k_scale=ksc_l, v_scale=vsc_l,
                                  intmax=intmax,
                                  kv_tile_blocks=kv_tile_blocks,
                                  interpret=cfg.interpret_kernels,
                                  split_tail_blocks=tail)


def paged_prefill_chunked(
    params,
    tokens: jax.Array,        # (1, C) one prompt chunk, right-padded
    pos0: jax.Array,          # () int32 absolute position of tokens[:, 0]
    last_rel: jax.Array,      # (1,) index of the chunk's true last token
    k_pool: jax.Array,        # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    table: jax.Array,         # (1, W) physical blocks covering every
    #                           position <= pos0 + C - 1 (logical order);
    #                           W must be the exact cover
    #                           ceil((pos0+C)/BS), or that cover rounded
    #                           up to a multiple of ceil(C/BS) with pad
    #                           entries = garbage block 0 (the CPU fast
    #                           path skips causal masking on the leading
    #                           blocks under exactly this guarantee)
    blk: jax.Array,           # (C,) int32 physical block per chunk row
    off: jax.Array,           # (C,) int32 row within that block
    cfg: ModelConfig,
    k_scale: jax.Array = None,   # (L, N, Hkv, BS) f32 scale pools (int8)
    v_scale: jax.Array = None,
    kv_tile_blocks: int = 1,     # static: pool blocks per kernel kv step
):
    """One chunk of a chunked prefill. Per layer: scatter the chunk's K/V
    rows into the pool at (blk, off) — pad rows route to garbage block 0 —
    then run chunk-queries-over-pool attention through ``table``. The
    scatter comes *first*, so the attention sees [cached prefix ‖ earlier
    chunks ‖ this chunk] as one logical KV stream and the positional causal
    mask does the rest; the pool update (instead of a returned K/V stack)
    is also what the next chunk of the same prompt resumes from. With an
    int8 pool the chunk's rows are quantized before the scatter, so the
    chunk attends its *own* rows through the same dequant path as the
    prefix — every reader of a given token sees identical values.

    Returns (chunk-last-token logits (1, V), new k_pool, new v_pool[, new
    k_scale, new v_scale]). The logits matter only for the final chunk
    (they seed decoding); computing them per chunk costs one (1, d) @
    (d, V) matmul. ``pos0 == 0`` with a chunk covering the whole prompt
    degenerates to ``paged_prefill``'s math, which is what the
    chunked-vs-one-shot greedy-equality test pins.
    """
    B, C = tokens.shape
    params = maybe_cast_params(params, cfg)
    dh = cfg.head_dim_
    premult, intmax = attn_mod._mode(cfg)
    quantized = k_pool.dtype == jnp.int8
    positions = pos0 + jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32),
                                        (B, C))
    x = embed(params["embed"], tokens, cfg)
    Hkv = cfg.n_kv_heads
    h_idx = jnp.arange(Hkv)
    qpos0 = jnp.broadcast_to(pos0, (B,)).astype(jnp.int32)

    def body(x, xs):
        if quantized:
            bp, kp_l, vp_l, ksc_l, vsc_l = xs
        else:
            bp, kp_l, vp_l = xs
            ksc_l = vsc_l = None
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        q, k, v = attn_mod._project_qkv(bp["mixer"], h, cfg, positions)
        rows_k = jnp.swapaxes(k[0], 0, 1)             # (C, Hkv, Dh)
        rows_v = jnp.swapaxes(v[0], 0, 1)
        if quantized:
            rows_k, sc_k = attn_mod.quantize_kv(rows_k)   # (C, Hkv) scales
            rows_v, sc_v = attn_mod.quantize_kv(rows_v)
            ksc_l = ksc_l.at[blk[:, None], h_idx[None, :],
                             off[:, None]].set(sc_k)
            vsc_l = vsc_l.at[blk[:, None], h_idx[None, :],
                             off[:, None]].set(sc_v)
        kp_l = kp_l.at[blk[:, None], h_idx[None, :], off[:, None], :].set(
            rows_k.astype(kp_l.dtype))
        vp_l = vp_l.at[blk[:, None], h_idx[None, :], off[:, None], :].set(
            rows_v.astype(vp_l.dtype))
        q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
        o = _chunk_attention(q, kp_l, vp_l, table, qpos0, cfg, intmax,
                             ksc_l, vsc_l, kv_tile_blocks)
        y = attn_mod._out_proj(bp["mixer"], o, cfg)
        x = x + y
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg)
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        x = shard_act(x + f, ("batch", "seq", "act_embed"))
        if quantized:
            return x, (kp_l, vp_l, ksc_l, vsc_l)
        return x, (kp_l, vp_l)

    if quantized:
        x, (new_k, new_v, new_ksc, new_vsc) = jax.lax.scan(
            body, x, (params["blocks"], k_pool, v_pool, k_scale, v_scale))
    else:
        x, (new_k, new_v) = jax.lax.scan(body, x, (params["blocks"], k_pool,
                                                   v_pool))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x_last = jnp.take_along_axis(
        x, last_rel[:, None, None].astype(jnp.int32), axis=1)  # (1, 1, d)
    lg = logits(params["embed"], x_last, cfg)[:, 0]
    if quantized:
        return lg, new_k, new_v, new_ksc, new_vsc
    return lg, new_k, new_v


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _paged_attention(q, k_pool_l, v_pool_l, block_tables, new_len, cfg,
                     intmax, ksc_l=None, vsc_l=None, kv_tile_blocks=1,
                     split_k=1):
    """Fused-batch decode attention through the one shared dispatcher
    (``kernels/flash_decode_paged/ops.py``): grouped/tiled/split Pallas
    kernel on TPU or under ``cfg.interpret_kernels``, the pure-JAX gather
    oracle elsewhere (tile/split are layout knobs — same math)."""
    return flash_decode_paged_op(q, k_pool_l, v_pool_l, block_tables,
                                 new_len, k_scale=ksc_l, v_scale=vsc_l,
                                 intmax=intmax,
                                 kv_tile_blocks=kv_tile_blocks,
                                 split_k=split_k,
                                 interpret=cfg.interpret_kernels)


def paged_decode_step(
    params,
    tokens1: jax.Array,       # (B,) current token ids
    k_pool: jax.Array,        # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) tokens already in cache
    cfg: ModelConfig,
    k_scale: jax.Array = None,   # (L, N, Hkv, BS) f32 scale pools (int8)
    v_scale: jax.Array = None,
    kv_tile_blocks: int = 1,     # static: pool blocks per kernel kv step
    decode_split_k: int = 1,     # static: parallel KV partitions per lane
):
    """One continuous-batch decode step.

    Writes each sequence's new K/V row at logical position ``lengths[b]``
    (physical: table[b, pos // BS] offset pos % BS), attends over
    ``lengths + 1`` entries, and returns (logits (B, V), new pools[, new
    scale pools]). With an int8 pool the new row is quantized against its
    own amax before the write — per-row scales make the append O(1) — and
    attention dequantizes on gather. The caller advances its host-side
    lengths by one afterwards.
    """
    params = maybe_cast_params(params, cfg)
    B = tokens1.shape[0]
    BS = k_pool.shape[3]
    Hkv = cfg.n_kv_heads
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    premult, intmax = attn_mod._mode(cfg)
    quantized = k_pool.dtype == jnp.int8

    table = params["embed"]["embedding"].astype(dt)
    x1 = shard_act(table[tokens1], ("batch", "act_embed"))

    blk = jnp.take_along_axis(block_tables, (lengths // BS)[:, None],
                              axis=1)[:, 0]           # (B,) physical block
    off = lengths % BS
    new_len = lengths + 1
    h_idx = jnp.arange(Hkv)

    def body(x1, xs):
        if quantized:
            bp, kp_l, vp_l, ksc_l, vsc_l = xs
        else:
            bp, kp_l, vp_l = xs
            ksc_l = vsc_l = None
        h = rmsnorm(bp["ln1"], x1, cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, bp["mixer"]["wq"].astype(dt))
        k = jnp.einsum("bd,dhk->bhk", h, bp["mixer"]["wk"].astype(dt))
        v = jnp.einsum("bd,dhk->bhk", h, bp["mixer"]["wv"].astype(dt))
        if cfg.qk_norm:
            q = rmsnorm(bp["mixer"]["q_norm"], q, cfg.norm_eps)
            k = rmsnorm(bp["mixer"]["k_norm"], k, cfg.norm_eps)
        if cfg.rope_theta > 0:
            pos = lengths[:, None]                    # (B, 1): next position
            q = rope(q[:, :, None, :], pos[:, :, None], cfg.rope_theta)[:, :, 0]
            k = rope(k[:, :, None, :], pos[:, :, None], cfg.rope_theta)[:, :, 0]
        if quantized:
            k, k_sc = attn_mod.quantize_kv(k)         # (B, Hkv) row scales
            v, v_sc = attn_mod.quantize_kv(v)
            ksc_l = ksc_l.at[blk[:, None], h_idx[None, :],
                             off[:, None]].set(k_sc)
            vsc_l = vsc_l.at[blk[:, None], h_idx[None, :],
                             off[:, None]].set(v_sc)
        kp_l = kp_l.at[blk[:, None], h_idx[None, :], off[:, None], :].set(
            k.astype(kp_l.dtype))
        vp_l = vp_l.at[blk[:, None], h_idx[None, :], off[:, None], :].set(
            v.astype(vp_l.dtype))
        q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
        o = _paged_attention(q, kp_l, vp_l, block_tables, new_len, cfg,
                             intmax, ksc_l, vsc_l, kv_tile_blocks,
                             decode_split_k)
        y = jnp.einsum("bhk,hkd->bd", o, bp["mixer"]["wo"].astype(dt))
        x1 = x1 + y
        h2 = rmsnorm(bp["ln2"], x1, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2[:, None, :], cfg)
            f = f[:, 0]
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        if quantized:
            return x1 + f, (kp_l, vp_l, ksc_l, vsc_l)
        return x1 + f, (kp_l, vp_l)

    if quantized:
        x1, (new_k, new_v, new_ksc, new_vsc) = jax.lax.scan(
            body, x1, (params["blocks"], k_pool, v_pool, k_scale, v_scale))
    else:
        x1, (new_k, new_v) = jax.lax.scan(body, x1, (params["blocks"],
                                                     k_pool, v_pool))
    x1 = rmsnorm(params["final_norm"], x1, cfg.norm_eps)
    lg = logits(params["embed"], x1[:, None, :], cfg)[:, 0]
    if quantized:
        return lg, new_k, new_v, new_ksc, new_vsc
    return lg, new_k, new_v
