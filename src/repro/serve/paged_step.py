"""Model step functions over the paged KV cache (attention-family LMs).

These mirror ``models/lm.py``'s prefill/decode pair but speak the block-pool
layout instead of a contiguous per-request cache:

* ``paged_prefill``     — full-prompt forward (prompts right-padded to a
  block multiple; causality keeps pad junk out of the real tokens) returning
  the true-last-token logits plus the per-layer K/V to scatter into the pool.
* ``scatter_prefill``   — place a prefilled request's K/V into its allocated
  physical blocks (one fused device scatter).
* ``paged_decode_step`` — one token for the whole running batch: per layer,
  write the new K/V row through the block table, then run paged Softermax
  decode attention over the pool. Inactive batch slots carry block table 0
  (the pool's garbage block) and length 0, so their writes and reads are
  harmless and their logits are ignored by the engine.

Attention math is identical to the contiguous path (same Unnormed-Softmax-
Unit recurrence): on TPU / under ``cfg.interpret_kernels`` the Pallas
``flash_decode_paged`` kernel runs; elsewhere a pure-JAX gather fallback
keeps CPU tests fast.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_decode_paged import (flash_decode_paged,
                                              paged_decode_ref)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.layers import embed, logits, mlp, rmsnorm, rope
from repro.models.lm import maybe_cast_params
from repro.parallel.sharding import shard_act


def check_paged_support(cfg: ModelConfig) -> None:
    """Paged serving covers the GQA attention families; everything else
    stays on the static engine."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"paged serving: unsupported family {cfg.family!r}")
    if cfg.mla is not None or cfg.ssm is not None:
        raise ValueError("paged serving: MLA/SSM caches not supported")
    if cfg.moe.first_dense:
        raise ValueError("paged serving: leading dense head blocks "
                         "not supported")
    if cfg.window:
        raise ValueError("paged serving: sliding-window archs not supported")
    if cfg.opt_int8_kv:
        raise ValueError("paged serving: int8 KV pool not implemented "
                         "(ROADMAP follow-up)")


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def paged_prefill(
    params,
    tokens: jax.Array,       # (B, Sp) prompts right-padded to a block multiple
    last_pos: jax.Array,     # (B,) int32 index of the true last prompt token
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (true-last-token logits (B, V), k, v (L, B, Hkv, Sp, Dh))."""
    B, Sp = tokens.shape
    params = maybe_cast_params(params, cfg)
    positions = jnp.broadcast_to(jnp.arange(Sp, dtype=jnp.int32), (B, Sp))
    x = embed(params["embed"], tokens, cfg)

    def body(x, bp):
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        y, k, v = attn_mod.attention_apply(
            bp["mixer"], h, cfg, positions=positions, causal=True,
            return_kv=True)
        x = x + y
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg)
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        x = shard_act(x + f, ("batch", "seq", "act_embed"))
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x_last = jnp.take_along_axis(
        x, last_pos[:, None, None].astype(jnp.int32), axis=1)  # (B, 1, d)
    lg = logits(params["embed"], x_last, cfg)[:, 0]
    return lg, ks, vs


def scatter_prefill(
    k_pool: jax.Array,       # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    ks: jax.Array,           # (L, 1, Hkv, Sp, Dh) from paged_prefill (B=1)
    vs: jax.Array,
    block_ids: jax.Array,    # (nb,) int32 physical blocks, nb*BS == Sp
) -> Tuple[jax.Array, jax.Array]:
    L, _, Hkv, Sp, Dh = ks.shape
    BS = k_pool.shape[3]
    nb = Sp // BS

    def place(pool, seq):
        blocks = seq[:, 0].reshape(L, Hkv, nb, BS, Dh)
        blocks = jnp.moveaxis(blocks, 2, 1)          # (L, nb, Hkv, BS, Dh)
        return pool.at[:, block_ids].set(blocks.astype(pool.dtype))

    return place(k_pool, ks), place(v_pool, vs)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _paged_attention(q, k_pool_l, v_pool_l, block_tables, new_len, cfg,
                     intmax):
    if cfg.interpret_kernels:
        return flash_decode_paged(q, k_pool_l, v_pool_l, block_tables,
                                  new_len, intmax=intmax, interpret=True)
    if jax.default_backend() == "tpu":
        return flash_decode_paged(q, k_pool_l, v_pool_l, block_tables,
                                  new_len, intmax=intmax)
    return paged_decode_ref(q, k_pool_l, v_pool_l, block_tables, new_len,
                            intmax=intmax)


def paged_decode_step(
    params,
    tokens1: jax.Array,       # (B,) current token ids
    k_pool: jax.Array,        # (L, N, Hkv, BS, Dh)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) tokens already in cache
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One continuous-batch decode step.

    Writes each sequence's new K/V row at logical position ``lengths[b]``
    (physical: table[b, pos // BS] offset pos % BS), attends over
    ``lengths + 1`` entries, and returns (logits (B, V), new pools). The
    caller advances its host-side lengths by one afterwards.
    """
    params = maybe_cast_params(params, cfg)
    B = tokens1.shape[0]
    BS = k_pool.shape[3]
    Hkv = cfg.n_kv_heads
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    premult, intmax = attn_mod._mode(cfg)

    table = params["embed"]["embedding"].astype(dt)
    x1 = shard_act(table[tokens1], ("batch", "act_embed"))

    blk = jnp.take_along_axis(block_tables, (lengths // BS)[:, None],
                              axis=1)[:, 0]           # (B,) physical block
    off = lengths % BS
    new_len = lengths + 1
    h_idx = jnp.arange(Hkv)

    def body(x1, xs):
        bp, kp_l, vp_l = xs
        h = rmsnorm(bp["ln1"], x1, cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, bp["mixer"]["wq"].astype(dt))
        k = jnp.einsum("bd,dhk->bhk", h, bp["mixer"]["wk"].astype(dt))
        v = jnp.einsum("bd,dhk->bhk", h, bp["mixer"]["wv"].astype(dt))
        if cfg.qk_norm:
            q = rmsnorm(bp["mixer"]["q_norm"], q, cfg.norm_eps)
            k = rmsnorm(bp["mixer"]["k_norm"], k, cfg.norm_eps)
        if cfg.rope_theta > 0:
            pos = lengths[:, None]                    # (B, 1): next position
            q = rope(q[:, :, None, :], pos[:, :, None], cfg.rope_theta)[:, :, 0]
            k = rope(k[:, :, None, :], pos[:, :, None], cfg.rope_theta)[:, :, 0]
        kp_l = kp_l.at[blk[:, None], h_idx[None, :], off[:, None], :].set(
            k.astype(kp_l.dtype))
        vp_l = vp_l.at[blk[:, None], h_idx[None, :], off[:, None], :].set(
            v.astype(vp_l.dtype))
        q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
        o = _paged_attention(q, kp_l, vp_l, block_tables, new_len, cfg,
                             intmax)
        y = jnp.einsum("bhk,hkd->bd", o, bp["mixer"]["wo"].astype(dt))
        x1 = x1 + y
        h2 = rmsnorm(bp["ln2"], x1, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_mod.moe_apply(bp["ffn"], h2[:, None, :], cfg)
            f = f[:, 0]
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        return x1 + f, (kp_l, vp_l)

    x1, (new_k, new_v) = jax.lax.scan(body, x1, (params["blocks"],
                                                 k_pool, v_pool))
    x1 = rmsnorm(params["final_norm"], x1, cfg.norm_eps)
    lg = logits(params["embed"], x1[:, None, :], cfg)[:, 0]
    return lg, new_k, new_v
