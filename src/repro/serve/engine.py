"""Batched serving engine: prefill + decode with slot management.

``ServeEngine`` owns jitted prefill/decode closures and a KV-cache sized to
(max_batch, max_len). ``generate`` serves a batch of prompts to completion
(greedy or temperature sampling over the *softermax* distribution — the
serve-time logits softmax also runs through the paper's base-2 form).

Decoder-only LMs use this engine; whisper serving composes
``whisper_prefill``/``whisper_decode_step`` directly (static cross-KV). A
production scheduler would add paged KV blocks and per-slot admission on top
of the same step functions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.softermax import softmax_base2
from repro.models.registry import model_fns


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray           # (B, max_new)
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        if cfg.opt_bf16_params:
            # cast matrix params ONCE at load — decode steps then run on the
            # resident bf16 copy (the in-step cast is an identity)
            from repro.models.lm import maybe_cast_params
            params = maybe_cast_params(params, cfg)
        self.params = params
        self.max_len = max_len
        self.fns = model_fns(cfg)
        self._decode = jax.jit(
            lambda p, t, c: self.fns.decode_step(p, t, c))
        self._prefill = jax.jit(
            lambda p, b: self.fns.prefill(p, b, max_len),
            static_argnames=())

    def _sample(self, lg: jax.Array, key, temperature: float) -> jax.Array:
        # restrict to the real vocabulary (drop TP padding)
        lg = lg[:, :self.cfg.vocab_size]
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        p = softmax_base2(lg / temperature, fold_log2e=True)
        return jax.random.categorical(key, jnp.log(p + 1e-20)).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new: int,
                 temperature: float = 0.0, seed: int = 0) -> GenerateResult:
        """prompts: (B, S) int32 full-length prompts."""
        key = jax.random.PRNGKey(seed)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        lg, cache = self._prefill(self.params, batch)
        out = []
        tok = self._sample(lg, key, temperature)
        out.append(tok)
        for i in range(max_new - 1):
            key, sub = jax.random.split(key)
            lg, cache = self._decode(self.params, tok, cache)
            tok = self._sample(lg, sub, temperature)
            out.append(tok)
        return GenerateResult(np.stack([np.asarray(t) for t in out], 1),
                              max_new)
