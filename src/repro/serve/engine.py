"""Serving engines: static-slot batching and continuous batching over a
paged KV cache.

Two engines share the model zoo and the softermax sampling head:

* ``ServeEngine`` — the original static-slot engine: one jitted prefill +
  decode closure over a contiguous ``(max_batch, max_len)`` cache; a batch of
  prompts runs to completion together. Every model family works here
  (decoder-only LMs directly; whisper composes the step functions itself).
  Kept as the general-purpose fallback and as the baseline the throughput
  benchmark measures against.

* ``ContinuousEngine`` — the production path for attention-family LMs:
  per-request admission from a FIFO (``serve/scheduler.py``), KV in
  fixed-size physical blocks from a shared pool (``serve/kv_pool.py``),
  decode as ONE fused step over the whole running batch through per-request
  block tables (``serve/paged_step.py`` → ``kernels/flash_decode_paged``).
  Requests join the fused decode batch within the same step() as their
  prefill and leave the moment they finish, returning their blocks to the
  pool; when the pool runs dry, unreferenced prefix-cache blocks are evicted
  first and only then is the youngest request preempted and recomputed
  later. A radix-tree prefix cache (``serve/radix_cache.py``, on by
  default) shares prompt-prefix KV blocks between requests: admission
  charges only the uncached suffix, prefill runs offset-aware from the
  first uncached token, and finished requests release their prompt blocks
  — and their drained generated tokens — back to the tree, so multi-turn
  conversations readmit as near-full hits. With ``prefill_chunk > 0`` long
  prompts prefill in fixed-size chunks through the flash-prefill kernel
  (``kernels/flash_prefill_paged``): one chunk per request per step,
  interleaved with decode steps (``prefill_budget`` caps the *total* chunk
  tokens dealt per step across requests), each chunk attending the cached
  prefix and every earlier chunk directly out of the pool — no quadratic
  one-shot score matrix, no per-layer prefix gather. With
  ``kv_dtype="int8"`` (the default when ``cfg.opt_int8_kv`` is set) the
  pool stores K/V as int8 with per-row scales — half the gather bytes,
  ~2x the tokens at equal HBM — quantizing on scatter and dequantizing
  inside the paged kernels, fp32 accumulation throughout. ``submit()``
  enqueues, ``step()`` advances the world one iteration and reports freshly
  decoded tokens per request (streaming), ``run()`` drives to completion and
  returns per-request results plus throughput/latency metrics.

Softermax is load-bearing in both: decode attention is the paper's
Unnormed-Softmax-Unit recurrence (running IntMax + power-of-two rescales),
which is what lets the paged engine visit cache blocks in table order with
no pre-pass, and the serve-time logits softmax runs through the base-2 form.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.softermax import softmax_base2
from repro.models.registry import model_fns
from repro.serve.autotune import (AUTOTUNE_MODES, GridPlanner,
                                  default_candidates)
from repro.serve.faults import FAULT_REQ, FaultInjector, TransientFault
from repro.serve.guard import (EngineGuard, EngineSheddingError,
                               GuardSignals)
from repro.serve.kernel_costs import decode_launch_cost, prefill_launch_cost
from repro.serve.kv_pool import PagedKVCache, PoolExhausted
from repro.serve.paged_step import (check_paged_support, paged_decode_step,
                                    paged_prefill, paged_prefill_chunked,
                                    paged_prefill_suffix, scatter_prefill,
                                    scatter_prefill_offset,
                                    table_width_bucket)
from repro.serve.radix_cache import RadixCache
from repro.serve.scheduler import (FINISH_DEADLINE, FINISH_QUARANTINED,
                                   PREFILL, Request, Scheduler)
from repro.serve.telemetry import Telemetry


def sample_tokens(lg: jax.Array, key, temperature: float,
                  cfg: ModelConfig) -> jax.Array:
    """Greedy or temperature sampling over the softermax distribution."""
    lg = lg[:, :cfg.vocab_size]     # drop TP vocab padding
    if temperature <= 0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    p = softmax_base2(lg / temperature, fold_log2e=True)
    return jax.random.categorical(key, jnp.log(p + 1e-20)).astype(jnp.int32)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray           # (B, max_new)
    steps: int


class ServeEngine:
    """Static-slot batch engine (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        if cfg.opt_bf16_params:
            # cast matrix params ONCE at load — decode steps then run on the
            # resident bf16 copy (the in-step cast is an identity)
            from repro.models.lm import maybe_cast_params
            params = maybe_cast_params(params, cfg)
        self.params = params
        self.max_len = max_len
        self.fns = model_fns(cfg)
        self._decode = jax.jit(
            lambda p, t, c: self.fns.decode_step(p, t, c))
        self._prefill = jax.jit(
            lambda p, b: self.fns.prefill(p, b, max_len),
            static_argnames=())

    def _sample(self, lg: jax.Array, key, temperature: float) -> jax.Array:
        return sample_tokens(lg, key, temperature, self.cfg)

    def generate(self, prompts: np.ndarray, max_new: int,
                 temperature: float = 0.0, seed: int = 0) -> GenerateResult:
        """prompts: (B, S) int32 full-length prompts."""
        key = jax.random.PRNGKey(seed)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        lg, cache = self._prefill(self.params, batch)
        out = []
        tok = self._sample(lg, key, temperature)
        out.append(tok)
        for i in range(max_new - 1):
            key, sub = jax.random.split(key)
            lg, cache = self._decode(self.params, tok, cache)
            tok = self._sample(lg, sub, temperature)
            out.append(tok)
        return GenerateResult(np.stack([np.asarray(t) for t in out], 1),
                              max_new)


# ---------------------------------------------------------------------------
# Continuous batching over the paged pool
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineMetrics:
    steps: int = 0
    decode_steps: int = 0
    prefills: int = 0
    prefill_chunks: int = 0      # chunked-prefill model steps run
    preemptions: int = 0
    tokens_out: int = 0          # tokens sampled (includes later-discarded)
    tokens_discarded: int = 0    # sampled but thrown away by preemption
    wall_s: float = 0.0
    peak_blocks: int = 0
    # pool capacity (constant per engine; int8 pools fit ~2x the tokens of
    # a bf16 pool at equal HBM — see PagedKVCache.bytes_per_block)
    kv_dtype: str = ""           # resolved storage dtype name
    #                              ("float32"/"bfloat16"/"int8")
    pool_token_capacity: int = 0     # num_blocks * block_size
    kv_pool_bytes: int = 0           # device bytes held by the pool arrays
    # prefix-cache counters (zero when the cache is disabled)
    prefill_tokens: int = 0      # prompt tokens actually run through prefill
    prefix_hit_tokens: int = 0   # prompt tokens reused from the radix tree
    cache_evictions: int = 0     # blocks evicted from the tree
    cow_copies: int = 0          # partial tail blocks copied on write
    shared_blocks_peak: int = 0  # peak blocks referenced by >1 owner
    # resilience counters (PR 8; zero when faults/guard/deadlines are off)
    cancelled: int = 0           # client cancellations honored
    deadline_misses: int = 0     # requests cancelled on deadline/TTFT breach
    quarantined: int = 0         # requests cancelled by the readback audit
    shed: int = 0                # submissions refused while SHEDDING
    faults_injected: int = 0     # injector firings (mirror of the log)
    transient_retries: int = 0   # TransientFaults absorbed by retry
    readback_audits: int = 0     # scatter-readback integrity audits run

    @property
    def tok_per_s(self) -> float:
        """Delivered-token throughput (discarded work doesn't count)."""
        kept = self.tokens_out - self.tokens_discarded
        return kept / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def prefill_savings(self) -> float:
        """Ratio of prompt tokens submitted to prompt tokens computed —
        the prefix cache's prefill-work reduction (1.0 = no reuse)."""
        total = self.prefill_tokens + self.prefix_hit_tokens
        return total / max(self.prefill_tokens, 1)


class ContinuousEngine:
    """Continuous batching + paged KV serving engine (attention LMs)."""

    def __init__(self, cfg: ModelConfig, params, *,
                 block_size: int = 16, num_blocks: int = 128,
                 max_batch: int = 8, max_len: int = 512,
                 max_admit_per_step: int = 2, seed: int = 0,
                 prefix_cache: bool = True, evict_policy: str = "lru",
                 prefill_chunk: int = 0, prefill_budget: int = 0,
                 kv_dtype: Optional[str] = None,
                 kv_tile_blocks: int = 1, decode_split_k: int = 1,
                 autotune: str = "off",
                 autotune_candidates=None,
                 telemetry: Optional[Telemetry] = None,
                 clock: Optional[Callable[[], float]] = None,
                 faults: Optional[FaultInjector] = None,
                 guard: Optional[EngineGuard] = None,
                 deadline_s: Optional[float] = None,
                 ttft_budget_s: Optional[float] = None,
                 step_fault_retries: int = 3,
                 retry_backoff_s: float = 0.005):
        check_paged_support(cfg)
        self.cfg = cfg
        # Observability is strictly opt-in: with telemetry=None (default)
        # every hook site is one attribute load + None check. An attached
        # Telemetry shares its clock with the engine and scheduler (unless
        # ``clock`` overrides), so every lifecycle stamp — including
        # ManualClock test time — comes from one source.
        self.telemetry = telemetry
        self._clock: Callable[[], float] = clock or (
            telemetry.clock if telemetry is not None else time.monotonic)
        if cfg.opt_bf16_params:
            from repro.models.lm import maybe_cast_params
            params = maybe_cast_params(params, cfg)
        self.params = params
        self.block_size = block_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.max_admit_per_step = max_admit_per_step
        # Chunked prefill: long prompts are computed ``prefill_chunk``
        # tokens at a time through the flash-prefill kernel (one chunk per
        # prefilling request per step, interleaved with decode steps).
        # 0 disables it — prompts prefill in one shot as before. The chunk
        # is rounded up to a block multiple so chunk boundaries and block
        # boundaries line up and every non-final chunk scatters whole rows.
        if prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, "
                             f"got {prefill_chunk}")
        self.prefill_chunk = (-(-prefill_chunk // block_size) * block_size
                              if prefill_chunk else 0)
        # Prefill token budget per step: caps the TOTAL chunk tokens dealt
        # across requests each step (not one chunk per request), so a herd
        # of concurrent long prompts can't crowd decode steps out. 0 = no
        # cap. The oldest prefilling request always advances regardless,
        # so prefill can never livelock.
        if prefill_budget < 0:
            raise ValueError(f"prefill_budget must be >= 0, "
                             f"got {prefill_budget}")
        self.prefill_budget = prefill_budget
        # Kernel grid knobs (layout, not math — every setting computes the
        # same attention; tiling preserves the visit order exactly, split-K
        # reassociates the partition sums within fp rounding, the rescales
        # staying exact power-of-two shifts): ``kv_tile_blocks`` pool
        # blocks are gathered
        # per kv grid step of both paged kernels (T*block_size >= 128 rows
        # makes MXU-shaped tiles), and decode's KV walk is partitioned
        # across ``decode_split_k`` parallel lanes merged by the
        # associative Softermax combine. Both only reach the Pallas
        # kernels (TPU / interpret_kernels); the CPU ref path ignores
        # them. See serve/README.md "Kernel grid & tiling".
        if kv_tile_blocks < 1 or decode_split_k < 1:
            raise ValueError(
                f"kv_tile_blocks and decode_split_k must be >= 1, got "
                f"{kv_tile_blocks}/{decode_split_k}")
        self.kv_tile_blocks = kv_tile_blocks
        self.decode_split_k = decode_split_k
        if autotune not in AUTOTUNE_MODES:
            raise ValueError(f"autotune must be one of {AUTOTUNE_MODES}, "
                             f"got {autotune!r}")
        self.autotune = autotune
        # KV pool storage: None/"auto" follow cfg.opt_int8_kv (the
        # --optimized serving path falls back to the compute dtype when the
        # flag is off); "bf16"/"int8" force that storage. Resolution lives
        # in PagedKVCache so direct pool construction agrees.
        self.pool = PagedKVCache(cfg, num_blocks, block_size,
                                 kv_dtype=kv_dtype or "auto")
        self.quantized = self.pool.quantized
        self.prefix_cache = (RadixCache(self.pool, evict_policy)
                             if prefix_cache else None)
        self.sched = Scheduler(self.pool, max_batch, max_len,
                               cache=self.prefix_cache, clock=self._clock)
        self.nb_max = -(-max_len // block_size)
        # Resilience layer (serve/faults.py, serve/guard.py): both nullable
        # hooks following the telemetry pattern. Engine-level defaults for
        # per-request deadlines apply to every submit() without explicit
        # budgets; TransientFaults are absorbed by bounded exponential
        # retry (step_fault_retries attempts, retry_backoff_s base delay —
        # the backoff sleeps through ManualClock.advance when the clock
        # supports it, keeping fault tests deterministic).
        self.guard = guard
        self.default_deadline_s = deadline_s
        self.default_ttft_budget_s = ttft_budget_s
        if step_fault_retries < 0 or retry_backoff_s < 0:
            raise ValueError("step_fault_retries and retry_backoff_s "
                             "must be >= 0")
        self.step_fault_retries = step_fault_retries
        self.retry_backoff_s = retry_backoff_s
        self.faults: Optional[FaultInjector] = None
        self._fault_pressure_blocks = 0   # blocks held under FAULT_REQ
        self._step_logit_err = 0.0        # max audited error this step
        if faults is not None:
            self.attach_faults(faults)
        # Kernel grid autotuning (serve/autotune.py): "static" consults
        # the analytic cost model once, here, on the worst-case batch
        # (every row at max_len) and rebinds the grid knobs; "per-step"
        # keeps a live planner that re-ranks the warmed candidate grids
        # from each decode step's actual lengths vector. Either way the
        # candidate set is closed at construction — serving never
        # compiles a grid warmup didn't see.
        self.planner: Optional[GridPlanner] = None
        if autotune != "off":
            self.planner = GridPlanner(
                autotune_candidates
                or default_candidates(kv_tile_blocks, decode_split_k),
                n_q_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, block_size=block_size,
                kv_dtype=self.pool.kv_dtype,
                registry=telemetry.registry if telemetry else None)
            if autotune == "static":
                dec = self.planner.plan_decode(
                    np.full((max_batch,), max_len, np.int64),
                    table_width_bucket(self.nb_max, nb_max=self.nb_max))
                self.kv_tile_blocks = dec.kv_tile_blocks
                self.decode_split_k = dec.split_k
        # Telemetry-path decode LaunchCost memo. Exact: the kernel attends
        # lengths+1, and every cost term depends on a row only through
        # q = len // block_size (ceil((len+1)/BS) = q+1 and
        # ceil((len+1)/(T*BS)) = q//T + 1), so keying on the q-vector is
        # lossless and hits on every step that crosses no block boundary.
        self._cost_cache: Dict[tuple, object] = {}
        self.metrics = self._fresh_metrics()
        self._key = jax.random.PRNGKey(seed)
        # Decode batch rows are STABLE: a request keeps its row from
        # admission to eviction, and vacated rows idle as harmless zombies
        # (length 0, garbage block 0) until reused. That makes the sampled
        # (B,) token vector of step N directly the input of step N+1 — no
        # recomposition, no host sync in the decode loop. Token values are
        # materialized lazily (drain).
        self._rows: List[Optional[Request]] = [None] * max_batch
        self._vec = jnp.zeros((max_batch,), jnp.int32)
        self._pending: List = []     # [(device vector, [(req, epoch, row)])]

        # The pool travels through every jitted step as a trailing *pools
        # group — (k, v) for bf16/f32 storage, (k, v, k_scale, v_scale) for
        # int8 — so the engine's call sites are mode-agnostic: they splat
        # ``self._pools()`` in and rebind whatever comes back.
        np_ = 4 if self.quantized else 2

        def _sc(pools):
            return {"k_scale": pools[2], "v_scale": pools[3]} \
                if len(pools) == 4 else {}

        # greedy argmax is fused into the jitted steps so the common
        # (temperature 0) path never materializes logits on the host
        def _amax(lg):
            return jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32)

        def _prefill_fn(p, t, lp):
            lg, ks, vs = paged_prefill(p, t, lp, cfg,
                                       kv_quantize=self.quantized)
            return _amax(lg), lg, ks, vs

        # grid knobs are trace-time constants: static kwargs of the jit,
        # so the per-step planner can swap grids without retracing tricks
        # — each (tile, split, table-width) lands in its own cache entry,
        # all of which warmup() pre-compiles when autotuning is on
        def _decode_fn(p, t, bt, ln, *pools, tile=1, split=1):
            out = paged_decode_step(p, t, pools[0], pools[1], bt, ln, cfg,
                                    kv_tile_blocks=tile,
                                    decode_split_k=split,
                                    **_sc(pools))
            return (_amax(out[0]), out[0]) + tuple(out[1:])

        def _prefill_suffix_fn(p, t, pos0, last_rel, pt, pl, *pools):
            lg, ks, vs = paged_prefill_suffix(p, t, pos0, last_rel,
                                              pools[0], pools[1], pt, pl,
                                              cfg, **_sc(pools))
            return _amax(lg), lg, ks, vs

        def _prefill_chunk_fn(p, t, pos0, last_rel, pt, blk, off, *pools):
            out = paged_prefill_chunked(p, t, pos0, last_rel, pools[0],
                                        pools[1], pt, blk, off, cfg,
                                        kv_tile_blocks=self.kv_tile_blocks,
                                        **_sc(pools))
            return (_amax(out[0]), out[0]) + tuple(out[1:])

        def _scatter_fn(ks, vs, block_ids, *pools):
            return scatter_prefill(pools[0], pools[1], ks, vs, block_ids,
                                   **_sc(pools))

        def _scatter_off_fn(ks, vs, blk, off, *pools):
            return scatter_prefill_offset(pools[0], pools[1], ks, vs, blk,
                                          off, **_sc(pools))

        # On accelerators, donate the pools: they are rebound to the returned
        # arrays every call, so the update aliases in-place instead of
        # holding 2x pool memory. On CPU donation serializes dispatch and
        # breaks the async decode pipeline (~4x slower steps) — skip it.
        def _donate(first):
            if jax.default_backend() == "cpu":
                return ()
            return tuple(range(first, first + np_))

        self._prefill = jax.jit(_prefill_fn)
        self._prefill_suffix = jax.jit(_prefill_suffix_fn)
        self._prefill_chunk_fn = jax.jit(_prefill_chunk_fn,
                                         donate_argnums=_donate(7))
        self._scatter = jax.jit(_scatter_fn, donate_argnums=_donate(3))
        self._scatter_off = jax.jit(_scatter_off_fn,
                                    donate_argnums=_donate(4))
        self._decode = jax.jit(_decode_fn, donate_argnums=_donate(4),
                               static_argnames=("tile", "split"))

    # -- public API -------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int,
               temperature: float = 0.0,
               req_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               ttft_budget_s: Optional[float] = None,
               t_submit: Optional[float] = None,
               ttft_observed: bool = False) -> Request:
        """Enqueue one request; returns its (streaming) Request handle.
        ``deadline_s``/``ttft_budget_s`` override the engine defaults
        (None = engine default; the engine cancels on breach). While the
        guard is SHEDDING this raises ``EngineSheddingError`` — the
        degradation ladder's front door (counted in
        ``requests_shed_total``) — carrying the guard's
        ``retry_after_steps`` backoff hint. ``t_submit``/``ttft_observed``
        are the fleet-failover migration stamps (see Scheduler.submit)."""
        if self.guard is not None and not self.guard.submit_allowed():
            self.metrics.shed += 1
            if self.telemetry is not None:
                self.telemetry.on_shed()
            hint = self.guard.retry_after_steps()
            raise EngineSheddingError(
                "engine is shedding load (guard state: "
                f"{self.guard.state}; reason: {self.guard.last_reason}) — "
                f"retry after >= {hint} clean steps",
                retry_after_steps=hint)
        req = self.sched.submit(
            np.asarray(prompt, np.int32), max_new, temperature, req_id,
            deadline_s=(deadline_s if deadline_s is not None
                        else self.default_deadline_s),
            ttft_budget_s=(ttft_budget_s if ttft_budget_s is not None
                           else self.default_ttft_budget_s),
            t_submit=t_submit, ttft_observed=ttft_observed)
        if self.telemetry is not None:
            self.telemetry.on_submit(req)
        return req

    def cancel(self, req_id: int, reason: str = "cancelled") -> bool:
        """Client cancellation: terminate a queued or running request,
        freeing its blocks and radix pins mid-prefill or mid-decode. Safe
        against the async pipeline (the epoch bump staleness-guards any
        in-flight token vector). Idempotent — returns False when the id is
        not queued/running."""
        req = self.sched.cancel(req_id, reason)
        if req is None:
            return False
        self._sync_rows()
        self.metrics.cancelled += 1
        if reason == FINISH_DEADLINE:
            self.metrics.deadline_misses += 1
        if self.telemetry is not None:
            self.telemetry.on_cancel(req, reason)
        return True

    def attach_faults(self, faults: Optional[FaultInjector]) -> None:
        """Thread the fault injector through engine, scheduler, and pool
        (one nullable hook each). Attach AFTER ``warmup()`` — warmup's
        synthetic steps would otherwise consume the plan's step indices."""
        self.faults = faults
        self.sched.faults = faults
        self.pool.faults = faults

    def warmup(self) -> None:
        """Take the greedy serving path's compiles out of serving latency:
        jit shapes first (prefill/scatter per block-count bucket, decode per
        table-width bucket; writes only into the reserved garbage block),
        then a synthetic mini-workload through the real submit/step path so
        the one-time eager-op compiles (token fetches, host→device
        converts) happen now too; with the prefix cache on, the synthetic
        prompts share prefixes, so the suffix-prefill/COW path compiles a
        first set of buckets as well (other suffix shapes compile on first
        hit at serve time). The cache is flushed afterwards. Temperature-
        sampled requests use eager host-side sampling whose small one-time
        compiles are not covered. Call once before serving traffic."""
        if self.sched.has_work():
            raise RuntimeError(
                "warmup() must run before any requests are submitted "
                "(its synthetic workload would consume and discard them)")
        zeros = jnp.zeros
        if self.prefill_chunk:
            # chunked engines never run the one-shot step: compile the
            # chunk step once per table-width bucket (all writes land in
            # the reserved garbage block 0; inputs are shape-only — wide
            # tables with pos0=0 break the split-path table contract, so
            # outputs are garbage, but they are finite and discarded)
            C = self.prefill_chunk
            cq = C // self.block_size
            # exactly the serve-time bucket set: every cover width any
            # in-range request can produce, through the one shared policy
            widths = sorted({table_width_bucket(n, chunk_blocks=cq)
                             for n in range(1, self.nb_max + 1)})
            for w in widths:
                _, _, *pools = self._prefill_chunk_fn(
                    self.params, zeros((1, C), jnp.int32),
                    jnp.asarray(0, jnp.int32),
                    jnp.asarray([C - 1], jnp.int32),
                    zeros((1, w), jnp.int32),
                    zeros((C,), jnp.int32), zeros((C,), jnp.int32),
                    *self._pools())
                self._set_pools(pools)
        else:
            for nb in range(1, self.nb_max + 1):
                Sp = nb * self.block_size
                _, _, ks, vs = self._prefill(
                    self.params, zeros((1, Sp), jnp.int32),
                    jnp.asarray([Sp - 1], jnp.int32))
                self._set_pools(self._scatter(ks, vs,
                                              zeros((nb,), jnp.int32),
                                              *self._pools()))
        # per-step autotuning picks among these exact entries at serve
        # time, so the whole candidate × width grid compiles here — the
        # planner never triggers a mid-serve compile
        grids = (self.planner.candidates
                 if self.planner is not None and self.autotune == "per-step"
                 else ((self.kv_tile_blocks, self.decode_split_k),))
        for w in sorted({table_width_bucket(n, nb_max=self.nb_max)
                         for n in range(1, self.nb_max + 1)}):
            for (ti, sp) in grids:
                _, _, *pools = self._decode(
                    self.params, zeros((self.max_batch,), jnp.int32),
                    zeros((self.max_batch, w), jnp.int32),
                    zeros((self.max_batch,), jnp.int32), *self._pools(),
                    tile=ti, split=sp)
                self._set_pools(pools)

        bs = self.block_size
        for nb in range(1, self.nb_max + 1):
            plen = (nb - 1) * bs + 1
            try:
                self.submit(np.ones((plen,), np.int32), 2)
            except ValueError:
                break                      # trajectory exceeds max_len/pool
        while self.sched.has_work():
            self.step()
        # the synthetic workload's allocations shouldn't show up in the
        # serving stats (notably peak_in_use → metrics.peak_blocks), and
        # its prompts shouldn't linger in the prefix cache
        self.reset()

    def reset(self) -> None:
        """Zero every engine-side aggregate coherently — EngineMetrics,
        PoolStats, CacheStats, scheduler counters, the finished set, and
        any attached telemetry — so a run/reset/run sequence reports the
        second run exactly as a fresh engine would (the run/reset/re-run
        equality test pins this). The prefix-cache *tree* is flushed too:
        keeping cached KV while zeroing hit counters would make the second
        run's stats incoherent with its actual work. Refuses to run with
        requests in flight."""
        if self.sched.has_work():
            raise RuntimeError("reset() with requests queued or running")
        self.drain()
        # vacate the decode rows and zero the on-device token vector:
        # no running requests means every row is a zombie, and a stale
        # Request reference (or pending vector) surviving reset would
        # leak the previous run's objects into the next one
        self._rows = [None] * self.max_batch
        self._vec = jnp.zeros((self.max_batch,), jnp.int32)
        self._pending.clear()
        self._release_pool_pressure()    # injector-held blocks go back
        if self.faults is not None:
            self.faults.reset()
        if self.guard is not None:
            self.guard.reset()
        self.sched.finished.clear()
        self.sched.n_preemptions = 0
        self.sched.tokens_discarded = 0
        self.metrics = self._fresh_metrics()
        if self.prefix_cache is not None:
            from repro.serve.radix_cache import CacheStats
            self.prefix_cache.reset()
            self.prefix_cache.stats = CacheStats()
        from repro.serve.kv_pool import PoolStats
        self.pool.stats = PoolStats(self.pool.num_blocks)
        if self.telemetry is not None:
            self.telemetry.reset()

    def step(self) -> Dict[int, List[int]]:
        """Advance the world one iteration: admit+prefill (one *chunk* per
        prefilling request when chunked prefill is on — long prompts no
        longer stall in-flight decodes), join, one fused decode step,
        evict. Returns {req_id: fresh tokens} — temperature-sampled tokens
        appear here each step; greedy tokens normally stay on device until
        ``drain()`` (``run(on_token=...)`` drains every step for
        streaming), EXCEPT that with a prefix cache attached (the default)
        a step on which some request finishes drains the whole pipeline —
        the finishing request's generated tokens are published to the
        radix tree, which needs their values — so drained greedy tokens
        land in that step's events."""
        tel = self.telemetry
        inj = self.faults
        t0 = self._clock()
        events: Dict[int, List[int]] = {}
        self._step_logit_err = 0.0
        if inj is not None:
            inj.begin_step(self.metrics.steps, telemetry=tel)
            self._apply_fault_front(inj, tel)
        self._enforce_deadlines()
        self._sync_rows()

        max_admit: Optional[int] = self.max_admit_per_step
        budget = self.prefill_budget
        if self.guard is not None:
            max_admit = self.guard.effective_max_admit(
                max_admit if max_admit is not None else self.max_batch)
            budget = self.guard.effective_prefill_budget(budget)
        admitted = self.sched.admit(max_admit)
        if tel is not None:
            for req in admitted:
                tel.on_admit(req)
        if self.prefill_chunk:
            # admitted requests stay PREFILL; prefilling requests advance
            # one chunk each, oldest first, until the per-step prefill
            # token budget (if any) is spent — decodes keep their share of
            # every step even under a herd of long prompts
            for req in self.sched.chunk_schedule(self.prefill_chunk,
                                                 budget):
                self._do_prefill_chunk(req, events)
        else:
            for req in admitted:
                self._do_prefill(req, events)
        self._drain_if_finishing(events)
        self._evict_finished(tel)                # max_new == 1 requests

        before_discard = self.sched.tokens_discarded
        preempted = self._with_retry(self.sched.ensure_decode_blocks)
        self.metrics.preemptions += len(preempted)
        self.metrics.tokens_discarded += \
            self.sched.tokens_discarded - before_discard
        if tel is not None:
            for req in preempted:
                tel.on_preempt(req)
        self._sync_rows()
        if any(r.state != PREFILL for r in self.sched.running):
            self._do_decode_step(events)
            self._drain_if_finishing(events)
            self._evict_finished(tel)

        self.metrics.steps += 1
        if inj is not None:
            self.metrics.faults_injected = inj.faults_injected
        dt = self._clock() - t0
        self.metrics.wall_s += dt
        self.metrics.peak_blocks = self.pool.stats.peak_in_use
        self.metrics.shared_blocks_peak = self.pool.stats.peak_shared
        self.metrics.cow_copies = self.pool.stats.cow_copies
        if self.prefix_cache is not None:
            self.metrics.cache_evictions = self.prefix_cache.stats.evictions
        if self.guard is not None:
            self._observe_guard(t0, dt, tel)
        if tel is not None:
            tel.on_step_end(self, t0, dt)
        return events

    def _evict_finished(self, tel: Optional[Telemetry]) -> None:
        for req in self.sched.evict_finished():
            if tel is not None:
                tel.on_finish(req)

    def _sync_rows(self) -> None:
        """Vacate rows whose request left the running set (finished or
        preempted); the row idles as a zombie until reassigned."""
        live = {id(r) for r in self.sched.running}
        for i, r in enumerate(self._rows):
            if r is not None and id(r) not in live:
                self._rows[i] = None

    # -- resilience internals (faults / guard / deadlines) ----------------

    def _sleep(self, dt: float) -> None:
        """Clock-aware sleep: ManualClock advances (deterministic tests),
        a real clock sleeps for real (injected stalls cost real time)."""
        if dt <= 0:
            return
        adv = getattr(self._clock, "advance", None)
        if adv is not None:
            adv(dt)
        else:
            time.sleep(dt)

    def _with_retry(self, fn):
        """Bounded retry-with-backoff around a step phase that can raise
        ``TransientFault`` (injected or real). The wrapped phases are
        idempotent (``ensure_decode_blocks`` skips requests whose table
        already grew), so re-entry after a partial pass is safe."""
        delay = self.retry_backoff_s
        for attempt in range(self.step_fault_retries + 1):
            try:
                return fn()
            except TransientFault:
                if attempt >= self.step_fault_retries:
                    raise
                self.metrics.transient_retries += 1
                if self.telemetry is not None:
                    self.telemetry.on_retry()
                self._sleep(delay)
                delay *= 2

    def _apply_fault_front(self, inj: FaultInjector, tel) -> None:
        """The injections that hit at the top of a step: pool pressure,
        stalls, preemption storms, and the step-level transient fault."""
        # pool pressure: steal free blocks under the FAULT_REQ sentinel so
        # admission back-off, cache eviction, and preemption all feel REAL
        # scarcity through their normal paths; released when the window
        # closes (target 0)
        want = inj.pool_pressure_target(self.pool.num_blocks)
        if want > self._fault_pressure_blocks:
            take = min(want - self._fault_pressure_blocks,
                       self.pool.num_free)
            if take > 0:
                self.pool.alloc(FAULT_REQ, take)
                self._fault_pressure_blocks += take
        elif want == 0:
            self._release_pool_pressure()
        stall = inj.stall_seconds()
        if stall > 0:
            self._sleep(stall)
        n_storm = inj.preempt_storm_count()
        if n_storm:
            before_discard = self.sched.tokens_discarded
            victims = self.sched.force_preempt(n_storm)
            self.metrics.preemptions += len(victims)
            self.metrics.tokens_discarded += \
                self.sched.tokens_discarded - before_discard
            if victims:
                inj.record("preempt_storm_victims", step=inj.step_idx,
                           req_ids=[v.req_id for v in victims])
            if tel is not None:
                for v in victims:
                    tel.on_preempt(v)
        self._with_retry(inj.check_step_fault)

    def _release_pool_pressure(self) -> None:
        if self._fault_pressure_blocks > 0:
            self.pool.free(FAULT_REQ)
            self._fault_pressure_blocks = 0

    def _enforce_deadlines(self) -> None:
        """Cancel queued/running requests past their deadline or TTFT
        budget (reason "deadline"; counted in deadline_misses_total)."""
        now = self._clock()
        overdue = [r for r in
                   list(self.sched.waiting) + list(self.sched.running)
                   if (r.deadline_s is not None and
                       now - r.t_submit >= r.deadline_s) or
                      (r.ttft_budget_s is not None and
                       r.t_first_token == 0.0 and
                       now - r.t_submit >= r.ttft_budget_s)]
        for req in overdue:
            self.cancel(req.req_id, FINISH_DEADLINE)

    def _observe_guard(self, t0: float, dt: float, tel) -> None:
        """Assemble this step's ``GuardSignals`` from the live PR 6/7
        surfaces and advance the degradation ladder."""
        now = self._clock()
        waiting = self.sched.waiting
        queue_wait = max((now - r.t_submit for r in waiting), default=0.0)
        spike = self.faults.numerics_spike() if self.faults is not None \
            else 0.0
        err = max(self._step_logit_err, spike)
        if tel is not None and err > 0:
            tel.registry.gauge(
                "numerics_logit_error",
                "latest probe's max |full - int8| logit delta").set(err)
        sig = GuardSignals(pool_util=self.pool.utilization,
                           logit_error=err,
                           queue_wait=queue_wait,
                           queue_depth=len(waiting),
                           step_seconds=dt)
        change = self.guard.observe(sig, step=self.metrics.steps)
        if change is not None and tel is not None:
            tel.on_guard(*change, step=self.metrics.steps)
        elif tel is not None:
            tel.g_guard_state.set(float(self.guard.level))

    def _quarantine(self, req: Request, err: float) -> None:
        """The audited logit error of ``req``'s freshly scattered KV
        exceeded the quarantine bound: purge every tree node its blocks
        back (so no later prefix hit serves poisoned KV) and cancel the
        request. Runs right after join, before any decode step consumed
        the bad state."""
        if self.prefix_cache is not None:
            purged = self.prefix_cache.purge(req.req_id)
        else:
            purged = 0
        self.metrics.quarantined += 1
        if self.faults is not None:
            self.faults.record("quarantine", step=self.faults.step_idx,
                               req_id=req.req_id, logit_error=err,
                               purged_nodes=purged)
        self.cancel(req.req_id, FINISH_QUARANTINED)

    def _corrupt_request_blocks(self, req: Request) -> None:
        """kv_corrupt landing site: flip the payload of every block ONLY
        this request owns (refcount 1 — shared prefix blocks belong to
        other owners and the tree; the fault models a bad scatter of THIS
        request's fresh rows)."""
        blocks = [b for b in self.pool.blocks_of(req.req_id)
                  if self.pool.refcount(b) == 1]
        for b in blocks:
            self.pool.corrupt_block(b)
        self.faults.record("kv_corrupt", step=self.faults.step_idx,
                           req_id=req.req_id, blocks=blocks)
        if self.telemetry is not None:
            self.telemetry.on_fault("kv_corrupt_hit", self.faults.step_idx,
                                    req_id=req.req_id)

    def _readback_audit(self, req: Request, lg) -> float:
        """Scatter-readback KV-integrity audit: recompute the final prompt
        token's logits READING the just-scattered blocks out of the pool
        (1-token suffix prefill) and compare against the prefill's own
        final logits. Clean pools agree to within quantization error;
        corrupted blocks produce a large delta → quarantine. Returns the
        max-abs logit delta (0.0 when the prompt is too short to audit)."""
        plen = req.prompt_len
        m = plen - 1
        if m < 1:
            return 0.0
        bs = self.block_size
        tokens = np.zeros((1, bs), np.int32)
        tokens[0, 0] = req.prompt[m]
        table = np.asarray(self.pool.blocks_of(req.req_id), np.int32)
        nb_p = -(-m // bs)
        w = self._pow2_bucket(nb_p)
        pt = np.zeros((1, w), np.int32)
        pt[0, :nb_p] = table[:nb_p]
        _, lg2, _ks, _vs = self._prefill_suffix(
            self.params, jnp.asarray(tokens), jnp.asarray(m, jnp.int32),
            jnp.asarray([0], jnp.int32), jnp.asarray(pt),
            jnp.asarray([m], jnp.int32), *self._pools())
        # readback only — the recomputed K/V rows are NOT scattered
        V = self.cfg.vocab_size
        err = float(jnp.max(jnp.abs(lg2[:, :V] - lg[:, :V])))
        self.metrics.readback_audits += 1
        self._step_logit_err = max(self._step_logit_err, err)
        if self.telemetry is not None:
            self.telemetry.on_readback(req, err)
        return err

    def _audit_and_quarantine(self, req: Request, lg) -> None:
        """Post-join integrity pass: run the readback audit when the guard
        asks for it and quarantine on a bound breach."""
        g = self.guard
        if g is None or not g.config.readback_audit:
            return
        err = self._readback_audit(req, lg)
        if g.should_quarantine(err):
            self._quarantine(req, err)

    def drain(self) -> Dict[int, List[int]]:
        """Materialize every in-flight sampled-token vector into its
        request's ``tokens`` list. Returns {req_id: fresh tokens}."""
        tel = self.telemetry
        n = len(self._pending)
        t = self._clock() if (tel is not None and n) else 0.0
        events: Dict[int, List[int]] = {}
        for vec, rows in self._pending:
            arr = np.asarray(vec)                # host↔device sync point
            for req, epoch, row in rows:
                if req.epoch == epoch:           # not preempted since
                    tok = int(arr[row])
                    req.tokens.append(tok)
                    events.setdefault(req.req_id, []).append(tok)
        self._pending.clear()
        if tel is not None and n:
            tel.on_drain(t, self._clock() - t, n)
        return events

    def _drain_if_finishing(self, events: Dict[int, List[int]]) -> None:
        """With a prefix cache attached, finished requests publish their
        *generated* tokens to the radix tree — which needs the token
        values. Materialize the async pipeline on steps where something is
        about to finish (the sync is confined to those steps)."""
        if self.prefix_cache is None or not self._pending:
            return
        if any(r.done for r in self.sched.running):
            for rid, toks in self.drain().items():
                events.setdefault(rid, []).extend(toks)

    def run(self, on_token: Optional[Callable[[int, List[int]], None]] = None
            ) -> Dict[int, Request]:
        """Drive until every submitted request has finished. With an
        ``on_token`` callback, tokens are drained (synced) every step for
        low-latency streaming; without one the pipeline stays async (host
        syncs only for temperature sampling) and drains once at the end.
        In-flight vectors are (max_batch,) int32 — negligible to hold.
        ``metrics.wall_s`` is set to the true wall time of the drive,
        including the final drain (step() alone accumulates only host
        dispatch time, which understates async greedy work)."""
        t0 = self._clock()
        w0 = self.metrics.wall_s     # replace this run's per-step dispatch
        #                              times with its true wall time, while
        #                              staying cumulative across runs
        while self.sched.has_work():
            events = self.step()
            if on_token:
                for rid, toks in self.drain().items():
                    events.setdefault(rid, []).extend(toks)
                for rid, toks in events.items():
                    on_token(rid, toks)
        self.drain()
        self.metrics.wall_s = w0 + (self._clock() - t0)
        return self.pop_finished()

    def pop_finished(self) -> Dict[int, Request]:
        """Return-and-clear the finished set. Keeps a long-lived engine from
        accumulating every completed Request, and keeps consecutive run()
        calls from re-reporting earlier runs' results."""
        done = dict(self.sched.finished)
        self.sched.finished.clear()
        return done

    # -- internals --------------------------------------------------------

    def _fresh_metrics(self) -> EngineMetrics:
        """Zeroed counters with the engine-constant pool-capacity fields
        pre-stamped (valid before the first step, survive warmup's
        reset)."""
        return EngineMetrics(kv_dtype=self.pool.kv_dtype,
                             pool_token_capacity=self.pool.token_capacity,
                             kv_pool_bytes=self.pool.hbm_bytes)

    def _pools(self):
        """The pool arrays as the jitted steps' trailing *pools group."""
        if self.quantized:
            return (self.pool.k, self.pool.v, self.pool.k_scale,
                    self.pool.v_scale)
        return (self.pool.k, self.pool.v)

    def _set_pools(self, pools) -> None:
        if self.quantized:
            (self.pool.k, self.pool.v, self.pool.k_scale,
             self.pool.v_scale) = pools
        else:
            self.pool.k, self.pool.v = pools

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _prefill_full(self, req: Request):
        """Cold prefill: the whole prompt through ``paged_prefill``, K/V
        scattered block-aligned into the request's (all-fresh) blocks."""
        bs = self.block_size
        plen = req.prompt_len
        Sp = -(-plen // bs) * bs
        tokens = np.zeros((1, Sp), np.int32)
        tokens[0, :plen] = req.prompt
        greedy, lg, ks, vs = self._prefill(self.params, jnp.asarray(tokens),
                                           jnp.asarray([plen - 1], jnp.int32))
        blocks = jnp.asarray(self.pool.blocks_of(req.req_id), jnp.int32)
        self._set_pools(self._scatter(ks, vs, blocks, *self._pools()))
        return greedy, lg

    def _prefill_from_offset(self, req: Request, m: int):
        """Prefix-cache hit: only the uncached suffix (positions ``m..``)
        runs through the model; attention reads the shared prefix blocks
        out of the pool, and the suffix K/V rows scatter to per-row
        (block, offset) targets — the first may sit mid-block after a
        copy-on-write tail splice. Pad rows route to garbage block 0."""
        bs = self.block_size
        plen = req.prompt_len
        sl = plen - m
        Sp = -(-sl // bs) * bs
        tokens = np.zeros((1, Sp), np.int32)
        tokens[0, :sl] = req.prompt[m:]
        table = np.asarray(self.pool.blocks_of(req.req_id), np.int32)
        nb_p = -(-m // bs)               # prefix blocks incl. the COW tail
        w = self._pow2_bucket(nb_p)
        pt = np.zeros((1, w), np.int32)
        pt[0, :nb_p] = table[:nb_p]
        pos = m + np.arange(Sp)
        blk = np.zeros((Sp,), np.int32)
        off = np.zeros((Sp,), np.int32)
        blk[:sl] = table[pos[:sl] // bs]
        off[:sl] = pos[:sl] % bs
        greedy, lg, ks, vs = self._prefill_suffix(
            self.params, jnp.asarray(tokens), jnp.asarray(m, jnp.int32),
            jnp.asarray([sl - 1], jnp.int32), jnp.asarray(pt),
            jnp.asarray([m], jnp.int32), *self._pools())
        self._set_pools(self._scatter_off(ks, vs, jnp.asarray(blk),
                                          jnp.asarray(off), *self._pools()))
        return greedy, lg

    def _do_prefill(self, req: Request, events: Dict[int, List[int]]) -> None:
        tel = self.telemetry
        t = self._clock() if tel is not None else 0.0
        plen = req.prompt_len
        m = req.n_prefix_hit
        if m > 0:
            greedy, lg = self._prefill_from_offset(req, m)
        else:
            greedy, lg = self._prefill_full(req)
        req.n_prefilled = plen
        self.metrics.prefill_tokens += plen - m
        self.metrics.prefix_hit_tokens += m
        if tel is not None:
            tel.on_prefill(req, "prefill-suffix" if m > 0 else "prefill",
                           plen - m,
                           self._pow2_bucket(-(-plen // self.block_size)),
                           t, self._clock() - t)
        if self.faults is not None and self.faults.take_kv_corrupt():
            self._corrupt_request_blocks(req)      # bad scatter, post hoc
        self._join_decode(req, greedy, lg, events)
        if tel is not None:
            probe = tel.maybe_numerics_probe(self, req)
            if probe:
                self._step_logit_err = max(
                    self._step_logit_err,
                    float(probe.get("logit_error", 0.0)))
        self._audit_and_quarantine(req, lg)

    def _do_prefill_chunk(self, req: Request,
                          events: Dict[int, List[int]]) -> None:
        """Advance one prefilling request by one chunk: compute + scatter
        ``prefill_chunk`` prompt tokens through the flash-prefill step (the
        chunk attends the cached prefix and every earlier chunk straight
        out of the pool). The final chunk's last-token logits seed decoding
        and the request joins the fused batch."""
        tel = self.telemetry
        t = self._clock() if tel is not None else 0.0
        bs = self.block_size
        C = self.prefill_chunk
        m, sl = self.sched.next_chunk(req, C)
        if m == req.n_prefix_hit:        # first chunk of this admission
            self.metrics.prefix_hit_tokens += m
        tokens = np.zeros((1, C), np.int32)
        tokens[0, :sl] = req.prompt[m:m + sl]
        table = np.asarray(self.pool.blocks_of(req.req_id), np.int32)
        cover = -(-(m + sl) // bs)       # blocks holding positions < m+sl
        # chunk tables bucket to multiples of the chunk's own block count
        # (not pow2) — see table_width_bucket for why that bound is also
        # the paged_prefill_chunked table contract
        cq = C // bs
        w = table_width_bucket(cover, chunk_blocks=cq)
        pt = np.zeros((1, w), np.int32)
        pt[0, :cover] = table[:cover]
        pos = m + np.arange(C)
        blk = np.zeros((C,), np.int32)   # pad rows -> garbage block 0
        off = np.zeros((C,), np.int32)
        blk[:sl] = table[pos[:sl] // bs]
        off[:sl] = pos[:sl] % bs
        greedy, lg, *pools = self._prefill_chunk_fn(
            self.params, jnp.asarray(tokens), jnp.asarray(m, jnp.int32),
            jnp.asarray([sl - 1], jnp.int32), jnp.asarray(pt),
            jnp.asarray(blk), jnp.asarray(off), *self._pools())
        self._set_pools(pools)
        req.n_prefilled = m + sl
        self.metrics.prefill_tokens += sl
        self.metrics.prefill_chunks += 1
        if tel is not None:
            # modeled cost of the chunk's paged-prefill kernel launch
            # (per layer); pos0 = m, one row, real table cover = cover
            cost = prefill_launch_cost(
                C, [m], [cover], w, n_q_heads=self.cfg.n_heads,
                n_kv_heads=self.cfg.n_kv_heads,
                head_dim=self.cfg.head_dim, block_size=self.block_size,
                kv_tile_blocks=self.kv_tile_blocks,
                kv_dtype=self.pool.kv_dtype)
            tel.on_prefill(req, "prefill-chunk", sl, w, t,
                           self._clock() - t, cost=cost,
                           launches=self.cfg.n_layers)
        if req.n_prefilled == req.prompt_len:
            if self.faults is not None and self.faults.take_kv_corrupt():
                self._corrupt_request_blocks(req)  # bad scatter, post hoc
            self._join_decode(req, greedy, lg, events)
            if tel is not None:
                probe = tel.maybe_numerics_probe(self, req)
                if probe:
                    self._step_logit_err = max(
                        self._step_logit_err,
                        float(probe.get("logit_error", 0.0)))
            self._audit_and_quarantine(req, lg)
        elif self.prefix_cache is not None:
            # publish completed chunks as they land — including a partial
            # tail block (its leaf is promoted in place by insert() once
            # later chunks fill the block, so no stale double-owner
            # survives) — so a request admitted while this long prompt is
            # still mid-prefill gets the maximal possible hit
            self.prefix_cache.insert(req.req_id,
                                     req.prompt[:req.n_prefilled])

    def _join_decode(self, req: Request, greedy, lg,
                     events: Dict[int, List[int]]) -> None:
        """Prefill completed: publish the prompt to the prefix cache,
        sample the first token from the final logits, and give the request
        a stable decode row."""
        if self.prefix_cache is not None:
            # publish the freshly computed prompt blocks right away so
            # requests admitted next step share with this in-flight one
            self.prefix_cache.insert(req.req_id, req.prompt)
        B = self.max_batch
        row = self._rows.index(None)     # guaranteed: running < max_batch
        self._rows[row] = req
        mask = np.zeros((B,), bool)
        mask[row] = True
        if req.temperature <= 0:
            # stays on device; materialized at the next drain
            self._pending.append((greedy, [(req, req.epoch, 0)]))
            self._vec = jnp.where(jnp.asarray(mask),
                                  jnp.broadcast_to(greedy, (B,)), self._vec)
        else:
            tok = int(sample_tokens(lg, self._next_key(), req.temperature,
                                    self.cfg)[0])
            req.tokens.append(tok)
            self._vec = jnp.where(jnp.asarray(mask),
                                  jnp.asarray(np.full((B,), tok, np.int32)),
                                  self._vec)
            events.setdefault(req.req_id, []).append(tok)
        req.n_generated = 1
        req.state = "decoding"
        # Dispatch-time stamp: exact when streaming (per-step drain keeps
        # the pipeline ≤1 step deep); optimistic by the pipeline depth for a
        # pure-async run() — t_finish (eviction) has the same convention,
        # so latencies stay internally consistent.
        req.t_first_token = self._clock()
        req.t_last_token = req.t_first_token
        self.metrics.prefills += 1
        self.metrics.tokens_out += 1
        if self.telemetry is not None:
            self.telemetry.on_first_token(req)

    def _pow2_bucket(self, need: int) -> int:
        """Decode/suffix table width via the stack-wide bucketing policy
        (``serve/paged_step.table_width_bucket``)."""
        return table_width_bucket(need, nb_max=self.nb_max)

    def _table_width(self, occ) -> int:
        """Decode block-table width covering the longest running request."""
        return self._pow2_bucket(
            max(self.pool.n_blocks_of(r.req_id) for _, r in occ))

    def _do_decode_step(self, events: Dict[int, List[int]]) -> None:
        tel = self.telemetry
        t = self._clock() if tel is not None else 0.0
        B = self.max_batch
        occ = [(i, r) for i, r in enumerate(self._rows) if r is not None]
        greedy_only = all(r.temperature <= 0 for _, r in occ)

        if greedy_only:
            tokens1 = self._vec          # previous step's vector, on device
        else:
            for rid, toks in self.drain().items():
                events.setdefault(rid, []).extend(toks)
            t1 = np.zeros((B,), np.int32)
            for i, req in occ:
                t1[i] = req.tokens[-1]
            tokens1 = jnp.asarray(t1)

        lengths = np.zeros((B,), np.int32)
        for i, req in occ:
            lengths[i] = req.n_cached
        w = self._table_width(occ)
        bt = np.zeros((B, w), np.int32)
        bt[[i for i, _ in occ]] = self.pool.table_array(
            [r.req_id for _, r in occ], w)

        # the kernel attends lengths+1 on every row (zombies included,
        # masked) — plan and account against what it actually does
        tile, split = self.kv_tile_blocks, self.decode_split_k
        plan = None
        if self.planner is not None and self.autotune == "per-step":
            plan = self.planner.plan_decode(lengths + 1, w)
            tile, split = plan.kv_tile_blocks, plan.split_k
        greedy, lg, *pools = self._decode(
            self.params, tokens1, jnp.asarray(bt), jnp.asarray(lengths),
            *self._pools(), tile=tile, split=split)
        self._set_pools(pools)

        if greedy_only:
            # async: token values stay on device until drained; bookkeeping
            # (finish, block growth) is purely count-based
            self._vec = greedy
            self._pending.append(
                (greedy, [(r, r.epoch, i) for i, r in occ]))
            for _, req in occ:
                req.n_generated += 1
                req.n_cached += 1
        else:
            toks = self._sample_rows(lg, [
                self._rows[i].temperature if self._rows[i] else 0.0
                for i in range(B)], greedy)
            for i, req in occ:
                tok = int(toks[i])
                req.tokens.append(tok)
                req.n_generated += 1
                req.n_cached += 1
                events.setdefault(req.req_id, []).append(tok)
            self._vec = jnp.asarray(toks)
        self.metrics.decode_steps += 1
        self.metrics.tokens_out += len(occ)
        if tel is not None:
            now = self._clock()
            if plan is not None:
                cost = plan.cost
            else:
                key = (w, tile, split,
                       (lengths // self.block_size).tobytes())
                cost = self._cost_cache.get(key)
                if cost is None:
                    if len(self._cost_cache) >= 4096:
                        self._cost_cache.clear()
                    cost = decode_launch_cost(
                        lengths + 1, w, n_q_heads=self.cfg.n_heads,
                        n_kv_heads=self.cfg.n_kv_heads,
                        head_dim=self.cfg.head_dim,
                        block_size=self.block_size,
                        kv_tile_blocks=tile, split_k=split,
                        kv_dtype=self.pool.kv_dtype)
                    self._cost_cache[key] = cost
            tel.on_decode_step(rows=len(occ), table_width=w, t_start=t,
                               dur=now - t, split_k=split,
                               kv_tile_blocks=tile, cost=cost,
                               launches=self.cfg.n_layers)
            if plan is not None:
                self.planner.observe_measured(plan, now - t)
            tel.on_decode_tokens([r for _, r in occ], now)

    def _sample_rows(self, lg: jax.Array, temps: List[float],
                     greedy_dev: Optional[jax.Array] = None) -> np.ndarray:
        """Per-row sampling; reuses the jit-fused argmax when provided."""
        lg = lg[:len(temps), :self.cfg.vocab_size]
        greedy = np.asarray(greedy_dev[:len(temps)] if greedy_dev is not None
                            else jnp.argmax(lg, axis=-1), np.int32)
        if all(t <= 0 for t in temps):
            return greedy
        tv = jnp.asarray([max(t, 1e-6) for t in temps], jnp.float32)
        p = softmax_base2(lg / tv[:, None], fold_log2e=True)
        samp = np.asarray(
            jax.random.categorical(self._next_key(), jnp.log(p + 1e-20)),
            np.int32)
        return np.where(np.asarray(temps) > 0, samp, greedy)
