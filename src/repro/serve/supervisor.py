"""Fleet supervision: per-replica health, step-watchdog heartbeats, and
journaled failover over N ``ContinuousEngine`` replicas.

The supervisor owns the synchronous fleet drive — one ``tick()`` is one
supervision round:

    1. evaluate the fleet fault plan (``replica_crash`` / ``replica_hang``
       via the PR 8 ``FaultInjector``; a crash kills the replica at the
       tick boundary, a hang makes its device unresponsive);
    2. run the step-watchdog: a serving replica that holds work but has
       not heartbeated for ``hang_grace_ticks`` supervision ticks (or
       ``hang_timeout_s`` wall seconds, when set) is declared hung;
    3. retry pending placements whose backoff expired, and enforce
       deadlines on requests the fleet has not managed to place;
    4. step + drain every serving replica (optionally in parallel
       threads — engines share nothing but read-only params), stamping
       heartbeats;
    5. pump freshly materialized tokens and terminal states into the
       tracker/journal, in replica order (deterministic journals).

**Failover recompute contract.** When a replica dies or hangs, every
request assigned to it is re-placed on a survivor with the prompt
``[prompt ‖ tokens-emitted-so-far]`` and ``max_new`` reduced by the
tokens already streamed. Greedy decode is deterministic and the repo's
engine paths are pinned exactly equal (PR 1/3/5 greedy-equality tests),
so the survivor's continuation is byte-identical to the unfailed run —
the same recompute mechanism the scheduler already uses for
preemption-readmit, lifted across replicas. The migration stamps
(``t_submit`` override + ``ttft_observed``) keep deadlines, E2E, and the
fleet-wide single TTFT sample measured from the client's original
submit.

A hung replica differs from a crashed one only in its afterlife: its
requests fail over identically, but when the device comes back the
supervisor first cancels the revoked engine requests (reason
``failover`` — freeing their blocks and radix pins, and making any
stale pipeline vector epoch-dead) and then returns the replica, empty,
to the routing pool. A crashed replica's engine is abandoned outright.

Placement failures (whole fleet shedding/full) ride bounded exponential
backoff: the delay starts from the ``EngineSheddingError.retry_after_steps``
hint when one was raised and doubles per consecutive refusal, bounded by
``max_attempts`` before the request resolves ``rejected``.
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serve.faults import FaultInjector
from repro.serve.frontend import (DONE, PENDING, PLACED, Assignment,
                                  RequestTracker, TrackedRequest)
from repro.serve.guard import EngineSheddingError
from repro.serve.invariants import check_invariants
from repro.serve.journal import Journal, state_digest
from repro.serve.router import Router
from repro.serve.scheduler import (FINISH_DEADLINE, FINISH_FAILOVER,
                                   FINISH_LENGTH, CapacityExceededError)


def snapshot_path(snapshot_dir: str, replica_idx: int) -> str:
    """Canonical per-replica snapshot file name inside a snapshot dir."""
    return os.path.join(snapshot_dir, f"replica{replica_idx}.snap")

# replica lifecycle (ReplicaHandle.state)
SERVING, HUNG, DEAD = "serving", "hung", "dead"


@dataclasses.dataclass
class ReplicaHandle:
    """One replica as the supervisor sees it: the engine plus fleet-side
    liveness. ``stalled`` mirrors the injected-hang window (the device is
    unresponsive; the drive loop cannot step it) — *detection* is the
    watchdog's job, which only ever looks at heartbeats."""

    idx: int
    engine: object
    state: str = SERVING
    stalled: bool = False
    revoked: List[int] = dataclasses.field(default_factory=list)
    last_beat_tick: int = -1
    last_beat_t: float = 0.0

    @property
    def name(self) -> str:
        return f"r{self.idx}"

    @property
    def accepting(self) -> bool:
        return self.state == SERVING

    def has_work(self) -> bool:
        return self.engine.sched.has_work()


class FleetSupervisor:
    """Owns the replica set, the router, the tracker, and the journal;
    drives supervision ticks (module docstring). Engines must be warmed
    up by the caller before serving (warmup resets engine state)."""

    def __init__(self, engines: List[object],
                 router: Optional[Router] = None,
                 tracker: Optional[RequestTracker] = None,
                 journal: Optional[Journal] = None,
                 faults: Optional[FaultInjector] = None,
                 clock: Optional[Callable[[], float]] = None,
                 hang_grace_ticks: int = 3,
                 hang_timeout_s: Optional[float] = None,
                 max_attempts: int = 8,
                 backoff_cap_ticks: int = 32,
                 check_invariants_each_tick: bool = False,
                 step_parallel: bool = False,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0):
        if not engines:
            raise ValueError("fleet needs at least one engine replica")
        self.replicas = [ReplicaHandle(i, e) for i, e in enumerate(engines)]
        self.clock = clock or time.monotonic
        self.router = router or Router()
        self.tracker = tracker or RequestTracker(clock=self.clock)
        self.journal = journal
        self.faults = faults
        self.hang_grace_ticks = hang_grace_ticks
        self.hang_timeout_s = hang_timeout_s
        self.max_attempts = max_attempts
        self.backoff_cap_ticks = backoff_cap_ticks
        self.check_invariants_each_tick = check_invariants_each_tick
        self.step_parallel = step_parallel
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.restore_info: List[Dict] = []   # set by resume()
        self.ticks = 0
        self._engine_map: Dict[int, TrackedRequest] = {}
        self._next_engine_rid = 0
        self._pool: Optional[ThreadPoolExecutor] = None
        reg = self.tracker.registry
        self.c_crashed = reg.counter(
            "fleet_replicas_crashed_total", "replicas lost to a crash")
        self.c_hung = reg.counter(
            "fleet_replicas_hung_total",
            "replicas declared hung by the step-watchdog")
        self.g_alive = reg.gauge(
            "fleet_replicas_alive", "replicas currently accepting work")
        self.g_alive.set(len(self.replicas))
        self.c_snapshots = reg.counter(
            "fleet_snapshots_written_total",
            "per-replica durable snapshots written to the snapshot dir")

    # -- front door --------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int,
               temperature: float = 0.0,
               deadline_s: Optional[float] = None,
               ttft_budget_s: Optional[float] = None) -> TrackedRequest:
        """Accept one request fleet-wide: journal it, track it, and try
        to place it immediately (a refused placement parks it in the
        pending queue with backoff — the client's stream is live either
        way)."""
        treq = self.tracker.create(prompt, max_new, temperature,
                                   deadline_s=deadline_s,
                                   ttft_budget_s=ttft_budget_s)
        if self.journal is not None:
            rec = dict(rid=treq.rid, prompt_len=int(treq.prompt.shape[0]),
                       max_new=max_new)
            if self.journal.log_prompts:
                rec["prompt"] = [int(x) for x in treq.prompt]
            self.journal.append("submit", **rec)
        self._try_place(treq, reason="submit")
        return treq

    def has_work(self) -> bool:
        return self.tracker.has_work()

    @property
    def alive(self) -> List[ReplicaHandle]:
        return [r for r in self.replicas if r.state == SERVING]

    # -- placement ---------------------------------------------------------

    def _try_place(self, treq: TrackedRequest, reason: str) -> bool:
        if treq.remaining <= 0:
            # every token already streamed before the failover — nothing
            # left to recompute, the request is simply complete
            self._terminal(treq, FINISH_LENGTH)
            return True
        rprompt = treq.recompute_prompt()
        replica = self.router.place(rprompt, self.replicas)
        hint = 1
        if replica is not None:
            erid = self._next_engine_rid
            self._next_engine_rid += 1
            treq.attempts += 1
            if self.journal is not None:
                self.journal.append(
                    "placement", rid=treq.rid, replica=replica.idx,
                    engine_rid=erid, attempt=treq.attempts - 1,
                    reason=reason, resume_base=len(treq.tokens))
            try:
                handle = replica.engine.submit(
                    rprompt, treq.remaining,
                    temperature=treq.temperature, req_id=erid,
                    deadline_s=treq.deadline_s,
                    ttft_budget_s=(treq.ttft_budget_s if not treq.tokens
                                   else None),
                    t_submit=treq.t_submit,
                    ttft_observed=bool(treq.tokens))
            except EngineSheddingError as e:
                hint = e.retry_after_steps
            except CapacityExceededError:
                # static-config mismatch: no replica will ever take it
                self._terminal(treq, "rejected")
                return False
            else:
                treq.assignment = Assignment(replica.idx, erid, handle,
                                             resume_base=len(treq.tokens))
                treq.state = PLACED
                treq.replicas.append(replica.idx)
                self._engine_map[erid] = treq
                return True
        # refused (fleet full/shedding): bounded exponential backoff,
        # seeded by the shed hint when the guard provided one
        if replica is None:
            treq.attempts += 1
        treq.state = PENDING
        if treq.attempts >= self.max_attempts:
            self._terminal(treq, "rejected")
            return False
        delay = min(self.backoff_cap_ticks,
                    max(hint, 1 << min(treq.attempts, 5)))
        treq.next_retry_tick = self.ticks + delay
        self.tracker.c_retries.inc()
        return False

    def _terminal(self, treq: TrackedRequest, reason: str) -> None:
        if self.journal is not None:
            self.journal.append("terminal", rid=treq.rid, reason=reason,
                                n_tokens=len(treq.tokens))
        self.tracker.on_terminal(treq, reason)

    # -- failure handling --------------------------------------------------

    def _fail(self, replica: ReplicaHandle, why: str) -> None:
        """Crash or hang: take the replica out of rotation and fail its
        in-flight requests over to survivors (recompute contract in the
        module docstring)."""
        replica.state = DEAD if why == "crash" else HUNG
        (self.c_crashed if why == "crash" else self.c_hung).inc()
        self.g_alive.set(len(self.alive))
        if self.journal is not None:
            self.journal.append("replica", replica=replica.idx,
                                event=why, tick=self.ticks)
        for treq in self.tracker.assigned_to(replica.idx):
            asg = treq.assignment
            if why == "hang":
                replica.revoked.append(asg.engine_rid)
            self._engine_map.pop(asg.engine_rid, None)
            treq.assignment = None
            treq.state = PENDING
            treq.n_failovers += 1
            self.tracker.c_failovers.inc()
            self._try_place(treq, reason=why)

    def _resume(self, replica: ReplicaHandle) -> None:
        """A hung replica's device came back: revoke the requests that
        already failed over (their blocks/pins free; stale vectors go
        epoch-dead) and rejoin the routing pool empty."""
        for erid in replica.revoked:
            replica.engine.cancel(erid, reason=FINISH_FAILOVER)
        replica.revoked.clear()
        replica.state = SERVING
        replica.last_beat_tick = self.ticks
        replica.last_beat_t = self.clock()
        self.g_alive.set(len(self.alive))
        if self.journal is not None:
            self.journal.append("replica", replica=replica.idx,
                                event="resume", tick=self.ticks)

    # -- the supervision tick ---------------------------------------------

    def tick(self) -> None:
        t = self.ticks
        # 1. fleet fault plan
        if self.faults is not None:
            self.faults.begin_step(t)
            for idx in self.faults.take_replica_crashes():
                r = self.replicas[idx]
                if r.state != DEAD:
                    self._fail(r, "crash")
            stalled = self.faults.replica_hang_targets()
        else:
            stalled = set()
        for r in self.replicas:
            r.stalled = r.idx in stalled and r.state != DEAD
            if r.state == HUNG and not r.stalled:
                self._resume(r)
        # 2. step-watchdog: heartbeats only (the injected stall above is
        # the *cause*; this is the generic detector)
        now = self.clock()
        for r in self.replicas:
            if r.state != SERVING or not r.has_work():
                continue
            stale_ticks = t - max(r.last_beat_tick, 0)
            stale_s = now - r.last_beat_t if r.last_beat_t else 0.0
            if stale_ticks > self.hang_grace_ticks or \
                    (self.hang_timeout_s is not None and
                     stale_s > self.hang_timeout_s):
                self._fail(r, "hang")
        # 3. pending queue: deadlines first, then expired backoffs
        for treq in self.tracker.live():
            if treq.state != PENDING:
                continue
            if (treq.deadline_s is not None and
                    now - treq.t_submit >= treq.deadline_s) or \
                    (treq.ttft_budget_s is not None and not treq.tokens and
                     now - treq.t_submit >= treq.ttft_budget_s):
                self._terminal(treq, FINISH_DEADLINE)
            elif t >= treq.next_retry_tick:
                self._try_place(treq, reason="retry")
        # 4. step + drain serving replicas (heartbeat on success; an
        # unhandled engine exception is an organic crash)
        active = [r for r in self.replicas
                  if r.state == SERVING and not r.stalled]
        stepping = [r for r in active if r.has_work()]
        if self.step_parallel and len(stepping) > 1:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=len(self.replicas))
            errs = list(self._pool.map(self._step_one, stepping))
        else:
            errs = [self._step_one(r) for r in stepping]
        for r, err in zip(stepping, errs):
            if err is not None:
                self._fail(r, "crash")
        beat_t = self.clock()
        for r in active:
            if r.state != SERVING:
                continue                 # crashed while stepping
            r.last_beat_tick = t
            r.last_beat_t = beat_t
        # 5. pump tokens + terminal states (replica order: deterministic
        # journal), then 6. invariants on every surviving pool
        for r in self.replicas:
            if r.state == SERVING and not r.stalled:
                self._pump(r)
        if self.check_invariants_each_tick:
            for r in self.replicas:
                if r.state == SERVING:
                    check_invariants(r.engine.pool, r.engine.prefix_cache)
        # 7. periodic durability: snapshot every replica + anchor the
        # journal AFTER the pump, so the snapshot and the journaled
        # streams describe the same instant
        if (self.snapshot_dir and self.snapshot_every > 0 and
                (t + 1) % self.snapshot_every == 0):
            self.save_snapshots()
        self.ticks += 1

    @staticmethod
    def _step_one(replica: ReplicaHandle) -> Optional[Exception]:
        try:
            replica.engine.step()
            replica.engine.drain()
        except Exception as e:          # noqa: BLE001 — any engine death
            return e                    # is a replica crash
        return None

    def _pump(self, replica: ReplicaHandle) -> None:
        """Publish this replica's freshly materialized tokens and terminal
        states to the journal + tracker. Token progress is read from the
        engine Request handles by POSITION (fleet position = resume_base +
        engine index), so an engine-internal preemption-recompute — which
        resets the handle's token list and regenerates the identical
        greedy prefix — never re-streams tokens the client already has."""
        for treq in self.tracker.assigned_to(replica.idx):
            asg = treq.assignment
            have = len(treq.tokens)
            total = asg.resume_base + len(asg.handle.tokens)
            if total > have:
                new = [int(x) for x in
                       asg.handle.tokens[have - asg.resume_base:]]
                if self.journal is not None:
                    self.journal.append("token", rid=treq.rid,
                                        replica=replica.idx, pos=have,
                                        toks=new)
                self.tracker.on_tokens(treq, new)
        for erid, req in replica.engine.pop_finished().items():
            treq = self._engine_map.pop(erid, None)
            if treq is None or req.finish_reason == FINISH_FAILOVER:
                continue                 # revoked after failover, or not ours
            if treq.state == DONE:
                continue
            self._terminal(treq, req.finish_reason)

    # -- durability --------------------------------------------------------

    def save_snapshots(self) -> List[Dict]:
        """Write an atomic snapshot of every serving replica to the
        snapshot dir, then append a snapshot-anchor record to the journal
        (replay cost from the anchor on is bounded by the suffix).
        Stalled/hung replicas are skipped — their device state is
        unreadable; their requests fail over anyway."""
        from repro.serve.snapshot import write_snapshot

        if not self.snapshot_dir:
            raise ValueError("supervisor has no snapshot_dir")
        os.makedirs(self.snapshot_dir, exist_ok=True)
        infos = []
        for r in self.replicas:
            if r.state != SERVING or r.stalled:
                continue
            infos.append(write_snapshot(
                r.engine, snapshot_path(self.snapshot_dir, r.idx)))
            self.c_snapshots.inc()
        if self.journal is not None:
            self.journal.anchor(tick=self.ticks,
                                replicas=[i["path"] for i in infos])
        return infos

    @classmethod
    def resume(cls, engine_factory: Callable[[], object], n_replicas: int,
               journal_path: str,
               snapshot_dir: Optional[str] = None,
               journal: Optional[Journal] = None,
               **kwargs) -> "FleetSupervisor":
        """Rebuild a fleet after process death: snapshot + journal-suffix
        recovery.

        Per replica, the recovery ladder is: read + apply + fsck the
        snapshot (warm start — the radix tree and pools survive, so
        shared prefixes re-hit instead of re-prefilling); on checksum,
        fingerprint, or invariant failure fall back to a cold engine from
        the factory.  The journal is then the authoritative request
        record: it is loaded with ``strict=False`` (a crash-torn tail
        drops only the unsynced suffix, counted in
        ``journal_tail_lost_total``), replayed from its last anchor, and
        every journaled request is adopted — terminal ones resolve
        immediately with their journaled streams; in-flight ones resubmit
        through the PR 9 recompute contract (``[prompt ‖ emitted]``,
        position-based dedup), which regenerates the byte-identical
        remainder because greedy decode is deterministic.

        ``journal`` is the NEW journal for the resumed process; its first
        record is a seeding anchor embedding the recovered state, so the
        new journal replays standalone.  Requires the prior journal to
        have logged prompts (``log_prompts=True``) if any request was
        still in flight.
        """
        from repro.serve.snapshot import requeue_inflight, restore_engine

        old = Journal.load(journal_path, strict=False)
        st = old.replay(from_anchor=True)

        engines, restore_info = [], []
        for i in range(n_replicas):
            spath = (snapshot_path(snapshot_dir, i)
                     if snapshot_dir else None)
            engine, _specs, info = restore_engine(engine_factory, spath)
            if info["mode"] == "warm":
                # journal is authoritative for request state: drop the
                # snapshot's queues (publishing their generated KV into
                # the radix tree first — that's the warm-restart payoff)
                # and let the adoption path below resubmit
                requeue_inflight(engine)
            engines.append(engine)
            restore_info.append(dict(info, replica=i))

        sup = cls(engines, journal=journal,
                  snapshot_dir=snapshot_dir, **kwargs)
        sup.restore_info = restore_info
        if old.tail_lost:
            sup.tracker.c_tail_lost.inc(old.tail_lost)
        if sup.journal is not None:
            # seeding anchor: the new journal replays standalone
            sup.journal.append("snapshot", digest=state_digest(st),
                               resumed_from=journal_path,
                               tail_lost=old.tail_lost)

        for rid in sorted(st.requests):
            r = st.requests[rid]
            if not r.finish_reason and r.prompt is None:
                raise ValueError(
                    f"request {rid} was in flight but the journal did not "
                    f"log prompts; resume needs Journal(log_prompts=True)")
            treq = sup.tracker.adopt(
                rid, np.asarray(r.prompt if r.prompt is not None else [],
                                np.int32),
                r.max_new, r.tokens, finish_reason=r.finish_reason,
                n_failovers=r.n_failovers)
            if not r.finish_reason:
                sup._try_place(treq, reason="restore")
        return sup

    # -- drive + observability --------------------------------------------

    def run_until_drained(self, max_ticks: int = 100_000) -> None:
        while self.tracker.has_work():
            if self.ticks >= max_ticks:
                raise RuntimeError(
                    f"fleet did not drain within {max_ticks} ticks")
            self.tick()

    def collect_metrics(self, prefix: str = ""):
        """Fleet-aggregated registry: every replica's telemetry registry
        (dead replicas included — their history is still truth) folded
        with the tracker's fleet registry via MetricRegistry.collect."""
        from repro.serve.metrics import MetricRegistry
        regs = [r.engine.telemetry.registry for r in self.replicas
                if r.engine.telemetry is not None]
        regs.append(self.tracker.registry)
        return MetricRegistry().collect(*regs, prefix=prefix)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        if self.journal is not None:
            self.journal.close()
