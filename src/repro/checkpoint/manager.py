"""Checkpoint manager: atomic, retained, mesh-agnostic (elastic) restore.

Save: the full train state (params, optimizer m/v/step, data-iterator state,
metadata) is flattened to path-keyed arrays and written as .npz into a
temp dir, then atomically renamed to ``step_<n>``. A retention policy prunes
old checkpoints. Writes go through a background thread so the train loop is
not blocked (async checkpointing).

Restore: arrays are loaded host-side and ``device_put`` with whatever
sharding the *current* mesh prescribes — a checkpoint written on a 16×16
mesh restores onto 2×16×16 (or a single CPU) unchanged. That property is the
elastic-rescale story: restart at a different pod count re-shards on load.

On a real multi-host fleet the save path would write per-host shards with a
global index (same layout as Orbax); this single-process implementation
gathers to host first but keeps the identical on-disk contract.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("checkpoint")

_SEP = "//"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths_leaves:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(tmpl, "shape") and tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any],
             extra_meta: Optional[Dict] = None) -> None:
        """state: {"params": tree, "opt": tree, "data": dict, ...}"""
        flat: Dict[str, np.ndarray] = {}
        meta = {"step": int(step), "keys": {}, **(extra_meta or {})}
        for name, tree in state.items():
            leaves = jax.tree_util.tree_leaves(tree)
            if all(isinstance(l, (int, float, str, bool, type(None)))
                   for l in leaves):
                meta[name] = tree  # plain metadata (e.g. data-iterator state)
                continue
            sub = _flatten(tree)
            meta["keys"][name] = sorted(sub.keys())
            flat.update({f"{name}{_SEP}{k}": v for k, v in sub.items()})

        self.wait()  # one in-flight save at a time

        def _write():
            try:
                t0 = time.time()
                tmp = os.path.join(self.dir, f".tmp_step_{step}")
                final = os.path.join(self.dir, f"step_{step:08d}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._retain()
                log.info("saved checkpoint step=%d (%.2fs)", step,
                         time.time() - t0)
            except BaseException as e:  # surfaced on next wait()/save()
                self._exc = e

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None
                ) -> Dict[str, Any]:
        """templates: same-structure trees (arrays or ShapeDtypeStructs);
        shardings: optional same-structure NamedSharding trees per name —
        arrays are device_put to them (mesh-agnostic restore)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))
        out: Dict[str, Any] = {"meta": meta}
        for name, template in templates.items():
            if name in meta and name not in meta["keys"]:
                out[name] = meta[name]
                continue
            prefix = f"{name}{_SEP}"
            flat = {k[len(prefix):]: npz[k] for k in npz.files
                    if k.startswith(prefix)}
            tree = _unflatten_into(template, flat)
            if shardings and name in shardings and shardings[name] is not None:
                tree = jax.tree_util.tree_map(
                    lambda a, s: jax.device_put(a, s), tree, shardings[name])
            out[name] = tree
        return out
