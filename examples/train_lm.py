"""End-to-end training driver: train an LM with Softermax attention.

Default: a ~100M-param llama-family model for a few hundred steps (the
deliverable-(b) configuration; takes hours on CPU, minutes on real devices):

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

CI-sized run (~2 minutes on CPU):

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 40
"""
import argparse

import jax

from repro.configs.base import ModelConfig, TrainConfig
from repro.data import SyntheticLMData
from repro.models.registry import get_config, model_fns, reduce_config
from repro.optim import adamw
from repro.train import make_train_step, train

PRESETS = {
    # ~103M params (tied embeddings), llama-style
    "100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
        tie_embeddings=True, softmax_impl="softermax",
        compute_dtype="float32"),
    "tiny": reduce_config(get_config("llama3.2-3b")),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--softmax", default="softermax",
                    choices=["softmax", "base2", "softermax",
                             "softermax_fixed"])
    args = ap.parse_args()

    cfg = PRESETS[args.preset].replace(softmax_impl=args.softmax)
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    from repro.models.schema import num_params
    print(f"model: {cfg.name}  params={num_params(fns.schema)/1e6:.1f}M  "
          f"softmax={cfg.softmax_impl}")

    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps//10, 1),
                     learning_rate=3e-4 if args.preset == "100m" else 3e-3,
                     checkpoint_every=max(args.steps // 3, 1))
    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, seed=0)
    step = jax.jit(make_train_step(fns.loss, tc))
    out = train(train_step=step, params=params, data=data, tc=tc,
                ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 20, 1))
    h = out["history"]
    print(f"loss: {h[0]:.4f} -> {h[-1]:.4f} over {len(h)} steps")


if __name__ == "__main__":
    main()
