"""Softermax-aware finetuning (§III, Table III workflow).

Pretrain with standard softmax → swap in the bit-faithful fixed-point
softermax (Table-I Q-formats, STE backward) → finetune → compare eval loss
against the no-finetune drop-in. Demonstrates the paper's central accuracy
claim: the finetuned fixed-point model recovers baseline quality.

    PYTHONPATH=src python examples/softermax_finetune.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.table3_accuracy import run


def main():
    r = run(pretrain_steps=60, finetune_steps=40)
    base = r["softmax"]
    print(f"{'variant':38s} eval_loss   delta")
    for k, v in r.items():
        print(f"{k:38s} {v:9.4f}   {v - base:+.4f}")
    drop_in = r["softermax_fixed_no_finetune"] - base
    finetuned = r["softermax_fixed"] - base
    print(f"\nfixed-point drop-in penalty: {drop_in:+.4f}; "
          f"after softermax-aware finetuning: {finetuned:+.4f}")


if __name__ == "__main__":
    main()
