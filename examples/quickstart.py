"""Quickstart: the Softermax algorithm family + kernels in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.softermax as sm
from repro.core import energy_model
from repro.kernels.softermax import softermax_op
from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           scale_queries)


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 128)) * 5, jnp.float32)

    # 1. The Figure-3 progression: all variants agree (float); fixed point
    #    is within the paper's pre-finetuning error budget.
    print("softmax_e  :", np.asarray(sm.softmax_e(x)[0, :4]))
    print("softermax  :", np.asarray(sm.softermax(x)[0, :4]))
    print("fixed-point:", np.asarray(sm.softermax_fixed(x)[0, :4]))
    print("max |softermax - softmax_2|:",
          float(jnp.abs(sm.softermax(x) - sm.softmax_base2(x)).max()))

    # 2. The Pallas row kernel (interpret mode on CPU) vs the closed form.
    y = softermax_op(x, interpret=True)
    print("kernel max err:", float(jnp.abs(y - sm.softermax(x)).max()))

    # 3. Flash attention with the softermax online recurrence.
    q = scale_queries(jnp.asarray(rng.normal(size=(1, 4, 128, 64)),
                                  jnp.float32), 64, base2=True)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    o = flash_attention(q, k, v, causal=True, interpret=True)
    print("flash-attn max err:",
          float(jnp.abs(o - attention_ref(q, k, v, causal=True)).max()))

    # 4. The hardware story (Table IV).
    for unit, r in energy_model.table4().items():
        print(f"{unit}: area×{r['area_ratio']:.2f} "
              f"energy×{r['energy_ratio']:.2f} "
              f"(paper ×{r['paper_area']:.2f}/×{r['paper_energy']:.2f})")


if __name__ == "__main__":
    main()
