"""Serving driver: batched generation with softermax decode attention.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b --reduced
"""
import argparse
import time

import numpy as np

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.max_new)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    res = eng.generate(prompts, args.max_new, temperature=args.temperature)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.max_new}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill+compile)")
    for i, row in enumerate(res.tokens[:2]):
        print(f"seq{i}:", row.tolist())


if __name__ == "__main__":
    main()
