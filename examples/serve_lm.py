"""Serving driver: continuous batching with softermax decode attention.

Submits a mixed-length batch of prompts to the paged ``ContinuousEngine``
and streams tokens as they decode. Runs the reduced (CPU smoke) config by
default; pass --full for the real model dimensions.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b
"""
import argparse
import time

import numpy as np

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import ContinuousEngine, Telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--full", action="store_true",
                    help="run the full-size config (default: reduced)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=64)
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix-tree prompt-prefix reuse")
    ap.add_argument("--kv-tile-blocks", type=int, default=1,
                    help="pool blocks per kernel kv grid step (TPU knob; "
                         "layout-only, identical outputs)")
    ap.add_argument("--decode-split-k", type=int, default=1,
                    help="parallel KV partitions per decode lane (TPU "
                         "knob; same attention up to fp summation order "
                         "of the split partials)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every request "
                         "(exercises the prefix cache)")
    args = ap.parse_args()

    import jax
    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduce_config(cfg)
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    tel = Telemetry()
    eng = ContinuousEngine(
        cfg, params, block_size=args.block_size,
        num_blocks=args.num_blocks, max_batch=args.requests,
        max_len=args.shared_prefix + args.prompt_len + args.max_new,
        prefix_cache=not args.no_prefix_cache,
        kv_tile_blocks=args.kv_tile_blocks,
        decode_split_k=args.decode_split_k,
        telemetry=tel)

    rng = np.random.default_rng(0)
    # mixed lengths: the whole point of per-request paged admission
    lens = rng.integers(args.prompt_len // 2, args.prompt_len + 1,
                        args.requests)
    system = rng.integers(1, cfg.vocab_size, (args.shared_prefix,))
    handles = [eng.submit(
        np.concatenate([system,
                        rng.integers(1, cfg.vocab_size, (n,))]
                       ).astype(np.int32),
        args.max_new, temperature=args.temperature) for n in lens]

    t0 = time.time()
    results = eng.run(on_token=lambda rid, toks:
                      print(f"  req{rid} += {toks}"))
    dt = time.time() - t0

    print(f"arch={cfg.name} requests={args.requests} "
          f"prompt_lens={lens.tolist()}")
    m = eng.metrics
    print(f"generated {m.tokens_out} tokens in {dt:.2f}s "
          f"({m.tokens_out / dt:.1f} tok/s incl. prefill+compile); "
          f"peak pool use {m.peak_blocks}/{args.num_blocks} blocks, "
          f"{m.preemptions} preemptions")
    if eng.prefix_cache is not None:
        cs = eng.prefix_cache.stats
        print(f"prefix cache: {cs.hit_tokens}/{cs.lookup_tokens} prompt "
              f"tokens reused ({100 * cs.hit_rate:.0f}%), prefill savings "
              f"{m.prefill_savings:.2f}x, shared-block peak "
              f"{m.shared_blocks_peak}, {m.cow_copies} COW copies, "
              f"{cs.evictions} evictions, "
              f"{eng.prefix_cache.cached_blocks} blocks cached at exit")
    # per-request latency table from the telemetry traces (same data the
    # registry aggregates into the p50/p90/p99 histograms)
    print(f"{'req':>4} {'prompt':>6} {'hit':>4} {'ttft_ms':>8} "
          f"{'tpot_ms':>8} {'e2e_ms':>8} {'toks':>5} {'preempt':>7}")
    for tr in sorted(tel.finished_traces, key=lambda t: t.req_id):
        print(f"{tr.req_id:>4} {tr.prompt_len:>6} {tr.n_prefix_hit:>4} "
              f"{tr.ttft * 1e3:>8.1f} {tr.tpot_mean * 1e3:>8.2f} "
              f"{tr.e2e * 1e3:>8.1f} {tr.n_tokens:>5} "
              f"{tr.n_preemptions:>7}")
    snap = tel.registry.snapshot()
    print(f"registry: cache_hit_tokens={snap.get('cache_hit_tokens', 0):.0f} "
          f"cache_hit_rate={snap.get('cache_hit_rate', 0.0):.2f} "
          f"pool_cow_copies={snap.get('pool_cow_copies', 0):.0f} "
          f"ttft_p99_ms={snap['serve_ttft_seconds']['p99'] * 1e3:.1f} "
          f"tpot_p99_ms={snap['serve_tpot_seconds']['p99'] * 1e3:.2f}")
    for h in handles[:2]:
        r = results[h.req_id]
        print(f"req{h.req_id} (ttft {r.ttft * 1e3:.0f}ms): {r.tokens}")


if __name__ == "__main__":
    main()
